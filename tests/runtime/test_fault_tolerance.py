"""Retry/degrade executor: outcome accounting, retries, partial serving,
elastic resize and checkpoint round-trips (the graceful-degradation layer
of ``repro.runtime.fault_tolerance``)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import faults
from repro.runtime.fault_tolerance import (OUTCOMES, CodedDataParallelExecutor,
                                           CodedDPConfig)


def _grad_fn(params, shard_batch):
    return {"w": jnp.mean(shard_batch["x"], axis=0)}


def _batch(k=16, d=4):
    return {"x": jnp.arange(k * d, dtype=jnp.float32).reshape(k, d)}


PARAMS = {"w": jnp.zeros(4)}


def test_every_round_gets_exactly_one_outcome():
    """The never-silently-drop invariant: outcome counts sum to rounds, and
    a round returns None iff it was dropped."""
    cfg = CodedDPConfig(p_gg=0.6, p_bb=0.8, packets=4, max_retries=1,
                        allow_partial=True)
    ex = CodedDataParallelExecutor(
        cfg, _grad_fn, seed=3,
        channel=faults.make_channel([("preempt", {"p_preempt": 0.4})]),
    )
    for _ in range(40):
        grads, info = ex.round(PARAMS, _batch())
        assert info["outcome"] in OUTCOMES
        assert (grads is None) == (info["outcome"] == "dropped")
    assert ex.rounds == 40
    assert sum(ex.outcomes.values()) == ex.rounds
    assert all(v >= 0 for v in ex.outcomes.values())


def test_defaults_reproduce_all_or_nothing_executor():
    """packets=1, no retries, no channel, no partial: the legacy contract —
    outcomes can only be on_time or dropped, successes counts on_time."""
    cfg = CodedDPConfig(p_gg=0.7, p_bb=0.7)
    ex = CodedDataParallelExecutor(cfg, _grad_fn, seed=0)
    for _ in range(30):
        ex.round(PARAMS, _batch())
    assert ex.outcomes["late"] == 0 and ex.outcomes["partial"] == 0
    assert ex.outcomes["on_time"] + ex.outcomes["dropped"] == 30
    assert ex.successes == ex.outcomes["on_time"]
    assert ex.timely_throughput == ex.successes / 30


def test_retries_turn_failures_into_late_rounds():
    """Same seed, same chain: adding retries can only move dropped rounds to
    late — it never costs an on-time round (coverage accumulates)."""
    cfg0 = CodedDPConfig(p_gg=0.5, p_bb=0.85)
    ex0 = CodedDataParallelExecutor(cfg0, _grad_fn, seed=1)
    for _ in range(30):
        ex0.round(PARAMS, _batch())
    cfg1 = CodedDPConfig(p_gg=0.5, p_bb=0.85, max_retries=3, backoff_base=2)
    ex1 = CodedDataParallelExecutor(cfg1, _grad_fn, seed=1)
    for _ in range(30):
        _, info = ex1.round(PARAMS, _batch())
        if info["outcome"] == "late":
            assert info["attempts"] > 1
    assert ex0.outcomes["dropped"] > 0      # the chain is genuinely bad
    served0 = ex0.outcomes["on_time"]
    served1 = ex1.outcomes["on_time"] + ex1.outcomes["late"]
    assert ex1.outcomes["late"] > 0
    assert served1 > served0


def test_partial_serving_requires_allow_partial():
    """A burst event wipes the packet TAIL fleet-wide: full decode becomes
    impossible that round while the layer-1 packet prefix still arrives —
    exactly the rounds allow_partial serves degraded instead of dropping."""
    kwargs = dict(p_gg=0.9, p_bb=0.3, packets=4, p1=1)
    ch = faults.make_channel([("burst", {"p_event": 0.3, "frac": 0.5})])
    ex_no = CodedDataParallelExecutor(
        CodedDPConfig(**kwargs), _grad_fn, seed=2, channel=ch)
    ex_yes = CodedDataParallelExecutor(
        CodedDPConfig(allow_partial=True, **kwargs), _grad_fn, seed=2,
        channel=ch)
    for _ in range(40):
        ex_no.round(PARAMS, _batch())
        g, info = ex_yes.round(PARAMS, _batch())
        if info["outcome"] == "partial":
            assert g is not None
    assert ex_no.outcomes["partial"] == 0
    assert ex_yes.outcomes["partial"] > 0
    # partial rounds are exactly the dropped rounds the layer-1 code saves:
    # same seed => same faults, and the other dispositions are untouched
    assert ex_yes.outcomes["on_time"] == ex_no.outcomes["on_time"]
    assert ex_yes.outcomes["partial"] + ex_yes.outcomes["dropped"] == (
        ex_no.outcomes["dropped"]
    )


def test_gradient_value_matches_uncoded_mean_whenever_served():
    cfg = CodedDPConfig(p_gg=0.95, p_bb=0.3)
    ex = CodedDataParallelExecutor(cfg, _grad_fn, seed=0)
    batch = _batch()
    want = np.asarray(jnp.mean(batch["x"].reshape(cfg.k, -1, 4), axis=(0, 1)))
    for _ in range(10):
        grads, info = ex.round(PARAMS, batch)
        if grads is not None:
            np.testing.assert_allclose(np.asarray(grads["w"]), want, rtol=1e-6)


def test_state_dict_roundtrips_outcomes():
    cfg = CodedDPConfig(p_gg=0.6, p_bb=0.8, packets=2, max_retries=1,
                        allow_partial=True)
    ex = CodedDataParallelExecutor(cfg, _grad_fn, seed=5)
    for _ in range(12):
        ex.round(PARAMS, _batch())
    d = ex.state_dict()
    ex2 = CodedDataParallelExecutor(cfg, _grad_fn, seed=99)
    ex2.load_state_dict(d)
    assert ex2.outcomes == ex.outcomes
    assert ex2.rounds == ex.rounds and ex2.successes == ex.successes
    np.testing.assert_array_equal(np.asarray(ex2.est.counts),
                                  np.asarray(ex.est.counts))


def test_load_state_dict_tolerates_legacy_checkpoints():
    """Checkpoints written before the outcomes field load with zero counts."""
    cfg = CodedDPConfig()
    ex = CodedDataParallelExecutor(cfg, _grad_fn, seed=0)
    d = ex.state_dict()
    del d["outcomes"]
    ex2 = CodedDataParallelExecutor(cfg, _grad_fn, seed=1)
    ex2.load_state_dict(d)
    assert ex2.outcomes == {name: 0 for name in OUTCOMES}


def test_mark_dead_feasibility_boundary():
    cfg = CodedDPConfig(n_workers=5, r=4, k=16)
    ex = CodedDataParallelExecutor(cfg, _grad_fn, seed=0)
    assert ex.decode_feasible
    ex.mark_dead(0)
    assert ex.decode_feasible          # 4*4 = 16 >= 16
    ex.mark_dead(1)
    assert not ex.decode_feasible      # 3*4 = 12 < 16


def test_dead_workers_contribute_no_packets():
    cfg = CodedDPConfig(n_workers=5, r=4, k=16, p_gg=0.99, p_bb=0.01)
    ex = CodedDataParallelExecutor(cfg, _grad_fn, seed=0)
    ex.mark_dead(2)
    mask, loads, _ = ex._attempt()
    assert not mask[2 * cfg.r:(2 + 1) * cfg.r].any()
    assert loads[2] == 0


def test_resize_grow_keeps_history_and_liveness():
    cfg = CodedDPConfig(n_workers=5, r=4, k=16)
    ex = CodedDataParallelExecutor(cfg, _grad_fn, seed=0)
    for _ in range(5):
        ex.round(PARAMS, _batch())
    ex.mark_dead(1)
    old_counts = np.asarray(ex.est.counts)
    ex.resize(8)
    assert ex.cfg.n_workers == 8
    assert ex.live.shape == (8,)
    assert not ex.live[1] and ex.live[5:].all()   # newcomers start live
    np.testing.assert_array_equal(np.asarray(ex.est.counts)[:5], old_counts)
    g, info = ex.round(PARAMS, _batch())          # still runs after resize
    assert info["outcome"] in OUTCOMES


def test_resize_shrink_with_survivor_selection():
    cfg = CodedDPConfig(n_workers=8, r=4, k=16)
    ex = CodedDataParallelExecutor(cfg, _grad_fn, seed=0)
    for _ in range(5):
        ex.round(PARAMS, _batch())
    counts = np.asarray(ex.est.counts)
    survivors = [6, 2, 4, 0, 7]
    ex.resize(5, survivors=survivors)
    assert ex.cfg.n_workers == 5
    np.testing.assert_array_equal(np.asarray(ex.est.counts),
                                  counts[survivors])
    g, info = ex.round(PARAMS, _batch())
    assert info["outcome"] in OUTCOMES
    assert sum(ex.outcomes.values()) == ex.rounds
