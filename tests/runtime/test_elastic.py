"""Elastic resharding utilities: ``reshard_state`` and ``remap_estimator``
across grow/shrink/survivor-selection resizes."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import lea
from repro.runtime.elastic import remap_estimator, reshard_state


def _estimator(n, seed=0):
    rng = np.random.default_rng(seed)
    return lea.EstimatorState(
        counts=jnp.asarray(rng.uniform(0, 10, (n, 4)), jnp.float32),
        prev_state=jnp.asarray(rng.integers(0, 2, n), jnp.int32),
        seen_prev=jnp.asarray(True),
    )


def test_reshard_state_round_trips_values():
    state = {
        "w": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
        "step": jnp.asarray(7),
    }
    sharding = jax.sharding.SingleDeviceSharding(jax.devices()[0])
    shardings = jax.tree.map(lambda _: sharding, state)
    out = reshard_state(state, shardings)
    assert jax.tree.structure(out) == jax.tree.structure(state)
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert a.sharding == sharding


def test_remap_identity_resize_is_a_no_op():
    est = _estimator(6)
    out = remap_estimator(est, 6, 6)
    np.testing.assert_array_equal(np.asarray(out.counts), np.asarray(est.counts))
    np.testing.assert_array_equal(np.asarray(out.prev_state),
                                  np.asarray(est.prev_state))
    assert bool(out.seen_prev) == bool(est.seen_prev)


def test_remap_grow_newcomers_get_pooled_prior():
    est = _estimator(4)
    out = remap_estimator(est, 4, 7)
    counts = np.asarray(est.counts)
    new = np.asarray(out.counts)
    np.testing.assert_array_equal(new[:4], counts)           # survivors keep history
    pooled = counts.mean(axis=0)
    for i in range(4, 7):
        np.testing.assert_allclose(new[i], pooled, rtol=1e-6)
        assert int(out.prev_state[i]) == 1                   # newcomers start good
    np.testing.assert_array_equal(np.asarray(out.prev_state)[:4],
                                  np.asarray(est.prev_state))


def test_remap_shrink_keeps_the_prefix():
    est = _estimator(8)
    out = remap_estimator(est, 8, 3)
    np.testing.assert_array_equal(np.asarray(out.counts),
                                  np.asarray(est.counts)[:3])
    np.testing.assert_array_equal(np.asarray(out.prev_state),
                                  np.asarray(est.prev_state)[:3])


def test_remap_with_explicit_survivors_permutes_history():
    est = _estimator(6)
    survivors = [5, 0, 3]
    out = remap_estimator(est, 6, 5, survivors=survivors)
    counts = np.asarray(est.counts)
    new = np.asarray(out.counts)
    np.testing.assert_array_equal(new[:3], counts[survivors])
    pooled = counts[survivors].mean(axis=0)   # prior pools over SURVIVORS
    for i in range(3, 5):
        np.testing.assert_allclose(new[i], pooled, rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(out.prev_state)[:3],
                                  np.asarray(est.prev_state)[survivors])


def test_remapped_estimator_drives_the_predictor():
    """The remapped state is a working EstimatorState: predicted_good_prob
    runs at the new width and survivors keep their predictions."""
    est = _estimator(5)
    before = np.asarray(lea.predicted_good_prob(est))
    out = remap_estimator(est, 5, 8)
    after = np.asarray(lea.predicted_good_prob(out))
    assert after.shape == (8,)
    np.testing.assert_allclose(after[:5], before, rtol=1e-6)
