"""Property tests for the Markov trajectory sampler: the associative-scan
path (the engine default) must reproduce the sequential ``lax.scan``
reference bit-for-bit, and the engine's ``round_chunk`` blocking must be
exact."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import markov, throughput
from repro.core.lea import LoadParams


@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(1, 24),
    rounds=st.integers(1, 200),
    seed=st.integers(0, 2**31 - 1),
)
def test_associative_scan_trajectory_bit_equals_scan(n, rounds, seed):
    rng = np.random.default_rng(seed)
    p_gg = jnp.asarray(rng.uniform(0.01, 0.99, n), jnp.float32)
    p_bb = jnp.asarray(rng.uniform(0.01, 0.99, n), jnp.float32)
    key = jax.random.PRNGKey(seed)
    assoc = markov.sample_trajectory(key, p_gg, p_bb, rounds)
    scan = markov.sample_trajectory_scan(key, p_gg, p_bb, rounds)
    assert assoc.shape == scan.shape == (rounds, n)
    np.testing.assert_array_equal(np.asarray(assoc), np.asarray(scan))


def test_associative_scan_trajectory_edge_probs():
    """Absorbing-ish chains (p near 0/1) keep exact agreement."""
    key = jax.random.PRNGKey(0)
    for pg, pb in [(1.0, 0.0), (0.0, 1.0), (1.0, 1.0), (0.0, 0.0)]:
        p_gg = jnp.full((5,), pg)
        p_bb = jnp.full((5,), pb)
        a = markov.sample_trajectory(key, p_gg, p_bb, 50)
        b = markov.sample_trajectory_scan(key, p_gg, p_bb, 50)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_trajectory_vmaps_and_stays_bit_equal():
    """The engine vmaps the sampler over sweep rows; both paths agree there."""
    keys = jax.random.split(jax.random.PRNGKey(3), 6)
    p_gg = jnp.asarray(np.random.default_rng(0).uniform(0.2, 0.9, (6, 8)), jnp.float32)
    p_bb = jnp.asarray(np.random.default_rng(1).uniform(0.2, 0.9, (6, 8)), jnp.float32)
    a = jax.vmap(lambda k, g, b: markov.sample_trajectory(k, g, b, 40))(keys, p_gg, p_bb)
    b = jax.vmap(lambda k, g, b: markov.sample_trajectory_scan(k, g, b, 40))(keys, p_gg, p_bb)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# round_chunk: blocked engine == unchunked engine, bit-for-bit
# ---------------------------------------------------------------------------

LP = LoadParams(n=15, kstar=99, ell_g=10, ell_b=3)
ALL = ("lea", "static", "static_equal", "static_single", "oracle")


def test_round_chunk_bit_identical_across_chunk_sizes():
    key = jax.random.PRNGKey(11)
    args = (jnp.full((15,), 0.8), jnp.full((15,), 0.7), 10.0, 3.0, 1.0, 250)
    full = throughput.simulate_strategies(key, LP, *args, strategies=ALL)
    for chunk in (1, 7, 64, 250, 999):   # includes non-divisors and > rounds
        got = throughput.simulate_strategies(
            key, LP, *args, strategies=ALL, round_chunk=chunk
        )
        np.testing.assert_array_equal(np.asarray(full), np.asarray(got)), chunk


def test_round_chunk_rejects_nonpositive():
    key = jax.random.PRNGKey(0)
    args = (jnp.full((15,), 0.8), jnp.full((15,), 0.7), 10.0, 3.0, 1.0, 8)
    try:
        throughput.simulate_strategies(key, LP, *args, round_chunk=0)
    except ValueError:
        return
    raise AssertionError("round_chunk=0 should raise")


def test_sweep_round_chunk_matches_unchunked():
    keys = jax.random.split(jax.random.PRNGKey(5), 3)
    p_gg = jnp.full((3, 15), 0.85)
    p_bb = jnp.full((3, 15), 0.65)
    a = throughput.sweep(keys, LP, p_gg, p_bb, 10.0, 3.0, 1.0, 120)
    b = throughput.sweep(keys, LP, p_gg, p_bb, 10.0, 3.0, 1.0, 120, round_chunk=31)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_rollout_scoring_equals_simulate_strategies():
    key = jax.random.PRNGKey(9)
    p_gg, p_bb = jnp.full((15,), 0.8), jnp.full((15,), 0.7)
    strategies = ("lea", "static", "oracle")
    states, loads, feasible = throughput.rollout(key, LP, p_gg, p_bb, 100, strategies)
    succ = throughput.score_rollout(states, loads, feasible, LP, 10.0, 3.0, 1.0)
    want = throughput.simulate_strategies(
        key, LP, p_gg, p_bb, 10.0, 3.0, 1.0, 100, strategies=strategies
    )
    np.testing.assert_array_equal(np.asarray(succ), np.asarray(want))


@settings(max_examples=15, deadline=None)
@given(
    n=st.integers(1, 12),
    rounds=st.integers(1, 100),
    seed=st.integers(0, 2**31 - 1),
    init_good=st.booleans(),
)
def test_explicit_init_trajectory_matches_sequential_reference(
    n, rounds, seed, init_good
):
    """sample_trajectory_from (the fault-process sampler): explicit round-0
    state, same parallel-prefix composition — must equal the sequential
    step_states recurrence bit-for-bit on the same key."""
    key = jax.random.PRNGKey(seed)
    p_stay1 = jnp.asarray(np.random.default_rng(seed).uniform(0.05, 0.95, n),
                          jnp.float32)
    p_stay0 = jnp.asarray(np.random.default_rng(seed + 1).uniform(0.05, 0.95, n),
                          jnp.float32)
    init = jnp.full((n,), int(init_good), jnp.int32)
    got = markov.sample_trajectory_from(key, p_stay1, p_stay0, rounds, init)
    assert got.shape == (rounds, n)
    # sequential reference: the same per-step uniforms in the same order
    ref = [np.asarray(init)]
    if rounds > 1:
        keys = jax.random.split(key, rounds - 1)
        for k in keys:
            # step_states is the (stay1, stay0) recurrence with p_gg=p_stay1,
            # p_bb=p_stay0 (state 1 stays with p_stay1, state 0 with p_stay0)
            ref.append(np.asarray(
                markov.step_states(k, jnp.asarray(ref[-1]), p_stay1, p_stay0)
            ))
    np.testing.assert_array_equal(np.asarray(got), np.stack(ref))


def test_explicit_init_round0_is_the_init():
    init = jnp.asarray([1, 0, 1, 0], jnp.int32)
    traj = markov.sample_trajectory_from(
        jax.random.PRNGKey(0), 0.5, 0.5, 10, init
    )
    np.testing.assert_array_equal(np.asarray(traj[0]), np.asarray(init))
