"""Property tests for the shape-polymorphic (traced-K*/ell, mask-padded)
engine — the PR's load-bearing invariants, per layer:

  * traced integer thresholds == the numpy static thresholds, exactly;
  * the ref DP with per-row threshold arrays == the ref DP with the shared
    static vector, bit-for-bit (the engine's CPU path);
  * the traced-threshold Pallas kernel (interpret) == the ref DP to float32
    round-off (the same tolerance the static kernel always had);
  * ``allocate_masked`` on a full-width pool == ``allocate`` with the
    equivalent static ``LoadParams``, bit-for-bit;
  * masked-allocate edge cases: all-masked rows and K*-infeasible pools set
    the EXPLICIT failure flag and assign zero load — never a silent success;
  * padded-vs-unpadded allocation on random pool sizes: valid workers'
    loads/i* match whenever the success-prob argmax is not within float
    round-off of a tie (the DP tail reduction width is the only difference);
  * the full engine: ``simulate_strategies_pool`` / ``sweep_pool`` on
    full-width pools == the static-``LoadParams`` engine, bit-for-bit,
    including non-stationary chains and round chunking;
  * K*-infeasible pools simulate without crashing and never succeed;
  * masked trajectory sampling freezes masked workers and is inert for
    full-width masks.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import lea, markov, throughput
from repro.core.lea import LoadParams, PoolLoad
from repro.kernels.poisson_binomial import (success_tails_pallas_w,
                                            success_tails_ref)


def _random_lp(rng, n) -> LoadParams:
    ell_b = int(rng.integers(1, 4))
    ell_g = ell_b + int(rng.integers(1, 8))
    kstar = int(rng.integers(n * ell_b + 1, n * ell_g + 1))
    return LoadParams(n=n, kstar=kstar, ell_g=ell_g, ell_b=ell_b)


# ---------------------------------------------------------------------------
# thresholds: traced integer ceil-div == numpy float64 ceil
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(n=st.integers(1, 40), seed=st.integers(0, 2**31 - 1))
def test_prefix_thresholds_traced_matches_numpy(n, seed):
    rng = np.random.default_rng(seed)
    lp = _random_lp(rng, n)
    want = lea.prefix_thresholds(lp)
    got = lea.prefix_thresholds_traced(
        jnp.asarray(lp.kstar), jnp.asarray(lp.ell_g), jnp.asarray(lp.ell_b),
        jnp.asarray(n), n,
    )
    np.testing.assert_array_equal(np.asarray(got), want)


def test_prefix_thresholds_traced_pads_infeasible_past_valid_pool():
    got = np.asarray(lea.prefix_thresholds_traced(
        jnp.asarray(9), jnp.asarray(4), jnp.asarray(1), jnp.asarray(3), 6
    ))
    lp = LoadParams(n=3, kstar=9, ell_g=4, ell_b=1)
    np.testing.assert_array_equal(got[:3], lea.prefix_thresholds(lp))
    assert (got[3:] == 7).all()             # sentinel n + 1 > every i~


# ---------------------------------------------------------------------------
# DP layer: per-row thresholds == shared thresholds, bit-for-bit (ref);
# traced-w Pallas kernel == ref to round-off
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(n=st.integers(2, 24), b=st.integers(1, 10), seed=st.integers(0, 2**31 - 1))
def test_ref_dp_rowwise_thresholds_bit_equal_shared(n, b, seed):
    rng = np.random.default_rng(seed)
    p = jnp.asarray(
        np.sort(rng.uniform(0, 1, (b, n)), axis=-1)[:, ::-1].copy(), jnp.float32
    )
    w = rng.integers(-2, n + 2, size=n).astype(np.int32)
    shared = success_tails_ref(p, jnp.asarray(w))
    rowwise = success_tails_ref(p, jnp.broadcast_to(jnp.asarray(w), (b, n)))
    np.testing.assert_array_equal(np.asarray(shared), np.asarray(rowwise))


@settings(max_examples=10, deadline=None)
@given(n=st.integers(2, 24), b=st.integers(1, 10), seed=st.integers(0, 2**31 - 1))
def test_pallas_traced_w_kernel_matches_ref(n, b, seed):
    rng = np.random.default_rng(seed)
    p = jnp.asarray(
        np.sort(rng.uniform(0, 1, (b, n)), axis=-1)[:, ::-1].copy(), jnp.float32
    )
    w = jnp.asarray(rng.integers(-2, n + 2, size=(b, n)), jnp.int32)
    pal = np.asarray(success_tails_pallas_w(p, w, interpret=True))
    ref = np.asarray(success_tails_ref(p, w))
    np.testing.assert_allclose(pal, ref, rtol=1e-6, atol=1e-7)


# ---------------------------------------------------------------------------
# allocate layer
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(n=st.integers(2, 20), b=st.integers(1, 8), seed=st.integers(0, 2**31 - 1))
def test_allocate_masked_full_width_bit_identical_to_allocate(n, b, seed):
    rng = np.random.default_rng(seed)
    lp = _random_lp(rng, n)
    p = jnp.asarray(rng.uniform(0, 1, (b, n)), jnp.float32)
    loads_s, istar_s = lea.allocate(p, lp)
    loads_m, istar_m, feasible = lea.allocate_masked(p, lea.pool_load(lp))
    np.testing.assert_array_equal(np.asarray(loads_s), np.asarray(loads_m))
    np.testing.assert_array_equal(np.asarray(istar_s), np.asarray(istar_m))
    assert bool(jnp.all(feasible))          # _random_lp keeps kstar <= n*ell_g


@settings(max_examples=20, deadline=None)
@given(n_valid=st.integers(1, 14), pad=st.integers(1, 12),
       seed=st.integers(0, 2**31 - 1))
def test_allocate_masked_padded_vs_unpadded_random_pool_sizes(n_valid, pad, seed):
    """Padded allocation == unpadded allocation for the valid workers.

    The only float difference between the two paths is the DP tail
    reduction width (padded rows sum extra exact zeros), so success probs
    agree to reduction round-off; away from argmax ties the loads and i*
    must match exactly, and masked slots always carry load 0.
    """
    rng = np.random.default_rng(seed)
    lp = _random_lp(rng, n_valid)
    n_max = n_valid + pad
    p_valid = rng.uniform(0, 1, n_valid).astype(np.float32)
    # garbage in the masked slots — must be ignored entirely
    p_pad = np.concatenate([p_valid, rng.uniform(0, 1, pad).astype(np.float32)])
    pool = lea.pool_load(lp, n=n_max)

    loads_u, istar_u = lea.allocate(jnp.asarray(p_valid), lp)
    loads_p, istar_p, feasible = lea.allocate_masked(jnp.asarray(p_pad), pool)
    assert bool(feasible)
    np.testing.assert_array_equal(np.asarray(loads_p)[n_valid:], 0)

    # success probs of both paths (the DP the argmax reads)
    p_sorted = np.sort(p_valid)[::-1].copy()
    probs_u = np.asarray(lea.success_prob_all_prefixes(jnp.asarray(p_sorted), lp))
    p_sorted_pad = np.concatenate([p_sorted, np.zeros(pad, np.float32)])
    probs_p = np.asarray(
        lea.success_prob_all_prefixes(jnp.asarray(p_sorted_pad), pool)
    )
    np.testing.assert_allclose(probs_p[:n_valid], probs_u, rtol=2e-6, atol=1e-7)
    np.testing.assert_array_equal(probs_p[n_valid:], 0.0)

    # exact equality away from reduction-round-off argmax ties
    top = np.max(probs_u)
    runners = probs_u[probs_u < top]
    gap = top - (runners.max() if runners.size else -1.0)
    if gap > 1e-5:
        assert int(istar_p) == int(istar_u)
        np.testing.assert_array_equal(np.asarray(loads_p)[:n_valid],
                                      np.asarray(loads_u))


def test_allocate_masked_all_masked_rows_fail_explicitly():
    rng = np.random.default_rng(0)
    pool = PoolLoad(kstar=jnp.asarray(5, jnp.int32), ell_g=jnp.asarray(4, jnp.int32),
                    ell_b=jnp.asarray(1, jnp.int32), mask=jnp.zeros((8,), bool))
    p = jnp.asarray(rng.uniform(0, 1, (3, 8)), jnp.float32)
    loads, i_star, feasible = lea.allocate_masked(p, pool)
    assert not bool(jnp.any(feasible))
    np.testing.assert_array_equal(np.asarray(loads), 0)


@settings(max_examples=15, deadline=None)
@given(n=st.integers(2, 12), seed=st.integers(0, 2**31 - 1))
def test_allocate_masked_infeasible_kstar_sets_failure_flag(n, seed):
    """kstar beyond the valid pool's capacity must set the explicit failure
    flag — never silently succeed."""
    rng = np.random.default_rng(seed)
    ell_b = int(rng.integers(1, 3))
    ell_g = ell_b + int(rng.integers(1, 5))
    n_valid = int(rng.integers(1, n + 1))
    kstar = n_valid * ell_g + int(rng.integers(1, 10))    # > capacity
    pool = PoolLoad(
        kstar=jnp.asarray(kstar, jnp.int32), ell_g=jnp.asarray(ell_g, jnp.int32),
        ell_b=jnp.asarray(ell_b, jnp.int32), mask=jnp.arange(n) < n_valid,
    )
    p = jnp.asarray(rng.uniform(0, 1, (4, n)), jnp.float32)
    _, _, feasible = lea.allocate_masked(p, pool)
    assert not bool(jnp.any(feasible))


# ---------------------------------------------------------------------------
# engine layer
# ---------------------------------------------------------------------------

ALL_STRATEGIES = ("lea", "static", "static_equal", "static_single", "oracle")


def test_simulate_strategies_pool_full_width_bit_identical():
    lp = LoadParams(n=15, kstar=99, ell_g=10, ell_b=3)
    key = jax.random.PRNGKey(7)
    args = (jnp.full((15,), 0.8), jnp.full((15,), 0.7), 10.0, 3.0, 1.0, 400)
    ref = throughput.simulate_strategies(key, lp, *args, strategies=ALL_STRATEGIES)
    got = throughput.simulate_strategies_pool(
        key, lea.pool_load(lp), *args, strategies=ALL_STRATEGIES
    )
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(got))
    # round chunking stays bit-identical on the pool path too
    chunked = throughput.simulate_strategies_pool(
        key, lea.pool_load(lp), *args, strategies=ALL_STRATEGIES, round_chunk=37
    )
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(chunked))


def test_simulate_strategies_pool_time_varying_chain_bit_identical():
    lp = LoadParams(n=6, kstar=24, ell_g=5, ell_b=2)
    key = jax.random.PRNGKey(3)
    rounds = 120
    rng = np.random.default_rng(0)
    p_gg = jnp.asarray(rng.uniform(0.4, 0.95, (rounds, 6)), jnp.float32)
    p_bb = jnp.asarray(rng.uniform(0.3, 0.9, (rounds, 6)), jnp.float32)
    args = (p_gg, p_bb, 5.0, 2.0, 1.0, rounds)
    ref = throughput.simulate_strategies(
        key, lp, *args, strategies=("lea", "static", "oracle")
    )
    got = throughput.simulate_strategies_pool(
        key, lea.pool_load(lp), *args, strategies=("lea", "static", "oracle")
    )
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(got))


def test_sweep_pool_full_width_bit_identical_to_sweep():
    lp = LoadParams(n=15, kstar=99, ell_g=10, ell_b=3)
    b, rounds = 5, 160
    keys = jnp.stack([jax.random.PRNGKey(i) for i in range(b)])
    rng = np.random.default_rng(1)
    p_gg = jnp.asarray(rng.uniform(0.6, 0.95, (b, 15)), jnp.float32)
    p_bb = jnp.asarray(rng.uniform(0.4, 0.9, (b, 15)), jnp.float32)
    ref = throughput.sweep(keys, lp, p_gg, p_bb, 10.0, 3.0, 1.0, rounds)
    pool = PoolLoad(
        kstar=jnp.full((b,), lp.kstar, jnp.int32),
        ell_g=jnp.full((b,), lp.ell_g, jnp.int32),
        ell_b=jnp.full((b,), lp.ell_b, jnp.int32),
        mask=jnp.ones((b, 15), bool),
    )
    got = throughput.sweep_pool(keys, pool, p_gg, p_bb, 10.0, 3.0, 1.0, rounds)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(got))


def test_heterogeneous_pool_rows_match_per_row_pool_runs():
    """One fused sweep_pool call over rows with different K*/ell/pool sizes
    == each row run alone through the masked engine (vmap consistency)."""
    n_max, rounds = 12, 96
    rows = [
        (LoadParams(n=12, kstar=30, ell_g=4, ell_b=1), 12),
        (LoadParams(n=8, kstar=20, ell_g=5, ell_b=2), 8),
        (LoadParams(n=5, kstar=9, ell_g=3, ell_b=1), 5),
    ]
    keys = jnp.stack([jax.random.PRNGKey(i) for i in range(len(rows))])
    rng = np.random.default_rng(2)
    p_gg = jnp.asarray(rng.uniform(0.5, 0.95, (len(rows), n_max)), jnp.float32)
    p_bb = jnp.asarray(rng.uniform(0.3, 0.9, (len(rows), n_max)), jnp.float32)
    pool = PoolLoad(
        kstar=jnp.asarray([lp.kstar for lp, _ in rows], jnp.int32),
        ell_g=jnp.asarray([lp.ell_g for lp, _ in rows], jnp.int32),
        ell_b=jnp.asarray([lp.ell_b for lp, _ in rows], jnp.int32),
        mask=jnp.stack([jnp.arange(n_max) < nv for _, nv in rows]),
    )
    fused = throughput.sweep_pool(
        keys, pool, p_gg, p_bb, 6.0, 2.0, 1.0, rounds,
        strategies=("lea", "static", "oracle"),
    )
    for ri, (lp, nv) in enumerate(rows):
        one = throughput.simulate_strategies_pool(
            keys[ri], lea.pool_load(lp, n=n_max), p_gg[ri], p_bb[ri],
            6.0, 2.0, 1.0, rounds, strategies=("lea", "static", "oracle"),
        )
        np.testing.assert_array_equal(np.asarray(fused[ri]), np.asarray(one))


def test_infeasible_kstar_pool_simulates_without_silent_success():
    lp = LoadParams(n=4, kstar=9, ell_g=3, ell_b=1)   # capacity 12 >= 9, fine
    pool = PoolLoad(
        kstar=jnp.asarray(50, jnp.int32),             # way past capacity
        ell_g=jnp.asarray(3, jnp.int32), ell_b=jnp.asarray(1, jnp.int32),
        mask=jnp.ones((4,), bool),
    )
    succ = throughput.simulate_strategies_pool(
        jax.random.PRNGKey(0), pool,
        jnp.full((4,), 0.95), jnp.full((4,), 0.1), 3.0, 1.0, 1.0, 64,
        strategies=ALL_STRATEGIES,
    )
    assert not bool(jnp.any(succ))


# ---------------------------------------------------------------------------
# trajectory sampling with masks
# ---------------------------------------------------------------------------

def test_sample_trajectory_mask_freezes_masked_workers():
    key = jax.random.PRNGKey(5)
    p_gg = jnp.full((10,), 0.6)
    p_bb = jnp.full((10,), 0.7)
    mask = jnp.arange(10) < 6
    traj = markov.sample_trajectory(key, p_gg, p_bb, 200, worker_mask=mask)
    assert bool(jnp.all(traj[:, 6:] == 1))            # frozen good
    # full-true mask is value-identical to no mask at all
    ref = markov.sample_trajectory(key, p_gg, p_bb, 200)
    full = markov.sample_trajectory(key, p_gg, p_bb, 200,
                                    worker_mask=jnp.ones((10,), bool))
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(full))
    # scan reference agrees under the mask too
    scan = markov.sample_trajectory_scan(key, p_gg, p_bb, 200, worker_mask=mask)
    np.testing.assert_array_equal(np.asarray(traj), np.asarray(scan))


def test_frozen_pad_chain_is_deterministically_good():
    """The sweeps padding convention (p_gg=1, p_bb=0) freezes workers in the
    good state even without a mask — stationary prob exactly 1."""
    key = jax.random.PRNGKey(9)
    p_gg = jnp.concatenate([jnp.full((4,), 0.5), jnp.ones((3,))])
    p_bb = jnp.concatenate([jnp.full((4,), 0.5), jnp.zeros((3,))])
    traj = markov.sample_trajectory(key, p_gg, p_bb, 100)
    assert bool(jnp.all(traj[:, 4:] == 1))
