"""Simulator-level tests: LEA vs static vs oracle (Thm 4.6 / 5.1 empirics)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import markov, throughput
from repro.core.lea import LoadParams

# Paper Sec. 6.1 setting: n=15, k=50, r=10, deg=2 -> K*=99; mu=(10,3), d=1.
LP = LoadParams(n=15, kstar=99, ell_g=10, ell_b=3)
MU_G, MU_B, D = 10.0, 3.0, 1.0

SCENARIOS = {
    1: (0.8, 0.8),     # pi_g = 0.5
    2: (0.8, 0.7),     # pi_g = 0.6
    3: (0.8, 0.533),   # pi_g = 0.7
    4: (0.9, 0.6),     # pi_g = 0.8
}


def _run(strategy, p_gg, p_bb, rounds=3000, seed=0):
    n = LP.n
    succ = throughput.simulate(
        jax.random.PRNGKey(seed), strategy, LP,
        jnp.full((n,), p_gg), jnp.full((n,), p_bb), MU_G, MU_B, D, rounds,
    )
    return throughput.timely_throughput(succ)


def test_stationary_distribution_values():
    for sc, (pgg, pbb) in SCENARIOS.items():
        pi = float(markov.stationary_good_prob(jnp.asarray(pgg), jnp.asarray(pbb)))
        want = {1: 0.5, 2: 0.6, 3: 0.7, 4: 0.8}[sc]
        assert abs(pi - want) < 0.02, (sc, pi)


@pytest.mark.parametrize("scenario", [1, 2, 3, 4])
def test_lea_beats_static_all_paper_scenarios(scenario):
    p_gg, p_bb = SCENARIOS[scenario]
    r_lea = _run("lea", p_gg, p_bb)
    r_static = _run("static", p_gg, p_bb)
    assert r_lea > r_static, (scenario, r_lea, r_static)
    # paper reports 1.38x–17.5x across these scenarios
    assert r_lea / max(r_static, 1e-6) > 1.2, (scenario, r_lea, r_static)


def test_lea_converges_to_oracle():
    """Theorem 5.1 empirically: R_LEA -> R* (genie) as M grows."""
    p_gg, p_bb = SCENARIOS[2]
    r_lea = _run("lea", p_gg, p_bb, rounds=8000, seed=3)
    r_star = _run("oracle", p_gg, p_bb, rounds=8000, seed=3)
    assert r_lea >= r_star - 0.02, (r_lea, r_star)
    assert r_lea <= r_star + 0.02  # cannot beat the genie beyond noise


def test_oracle_dominates_both():
    p_gg, p_bb = SCENARIOS[1]
    r_star = _run("oracle", p_gg, p_bb, rounds=4000)
    r_static = _run("static", p_gg, p_bb, rounds=4000)
    assert r_star >= r_static - 0.01


def test_simulate_rejects_unknown_strategy():
    with pytest.raises(ValueError):
        throughput.simulate(
            jax.random.PRNGKey(0), "nope", LP,
            jnp.full((15,), 0.8), jnp.full((15,), 0.8), MU_G, MU_B, D, 10,
        )


def test_markov_trajectory_matches_stationary_frequency():
    p_gg, p_bb = 0.9, 0.6
    traj = markov.sample_trajectory(
        jax.random.PRNGKey(1), jnp.full((4,), p_gg), jnp.full((4,), p_bb), 20000
    )
    freq = np.asarray(traj, dtype=np.float64).mean()
    pi = float(markov.stationary_good_prob(jnp.asarray(p_gg), jnp.asarray(p_bb)))
    assert abs(freq - pi) < 0.02
