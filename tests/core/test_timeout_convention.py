"""The shared short-round convention: every EAGER decode entry point raises
the same ``TimeoutError`` (same message shape) through the one
``_received_or_raise`` gate, float and exact alike — the jitted device paths
return ``ok=False`` instead (they cannot raise data-dependently)."""

import re

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.coded_ops import (CodeSpec, DecodeCache, ModpDecodeCache,
                                  _received_or_raise, coded_linear_gradient,
                                  coded_matmul, encode_dataset,
                                  encode_dataset_modp)

_MSG = r"round failed: \d+ < K\*=\d+ on-time results"


def _short_mask(spec):
    on_time = np.zeros(spec.nr, bool)
    on_time[: spec.recovery_threshold - 1] = True
    return on_time


def test_received_or_raise_message_and_success_path():
    spec = CodeSpec(n=5, r=3, k=6, deg_f=1)
    with pytest.raises(TimeoutError, match=_MSG):
        _received_or_raise(spec, _short_mask(spec))
    full = np.ones(spec.nr, bool)
    received = _received_or_raise(spec, full)
    np.testing.assert_array_equal(received,
                                  np.arange(spec.recovery_threshold))


def test_float_eager_paths_share_the_gate():
    rng = np.random.default_rng(0)
    spec = CodeSpec(n=5, r=3, k=6, deg_f=2)
    x = rng.normal(size=(spec.k, 2, 3)).astype(np.float32)
    y = rng.normal(size=(spec.k, 2)).astype(np.float32)
    coded = encode_dataset(spec, jnp.asarray(x), jnp.asarray(y))
    w = jnp.ones((3,), jnp.float32)
    short = _short_mask(spec)
    for call in (
        lambda: coded_matmul(coded, w, short),
        lambda: coded_matmul(coded, w, short, cache=DecodeCache(spec)),
        lambda: coded_linear_gradient(coded, w, short),
        lambda: coded_linear_gradient(coded, w, short, cache=DecodeCache(spec)),
        lambda: DecodeCache(spec).from_on_time(short),
    ):
        with pytest.raises(TimeoutError, match=_MSG):
            call()


def test_exact_path_shares_the_gate():
    rng = np.random.default_rng(0)
    spec = CodeSpec(n=5, r=3, k=6, deg_f=1)
    coded = encode_dataset_modp(
        spec, rng.integers(0, 997, size=(spec.k, 2, 3)).astype(np.int64)
    )
    with pytest.raises(TimeoutError, match=_MSG):
        ModpDecodeCache(coded.spec).from_on_time(_short_mask(spec))


def test_float_and_exact_messages_are_identical_in_shape():
    spec = CodeSpec(n=5, r=3, k=6, deg_f=1)
    short = _short_mask(spec)
    msgs = []
    for cache in (DecodeCache(spec), ModpDecodeCache(spec)):
        with pytest.raises(TimeoutError) as ei:
            cache.from_on_time(short)
        msgs.append(str(ei.value))
    assert msgs[0] == msgs[1]
    assert re.fullmatch(_MSG, msgs[0])


def test_cache_never_pays_a_miss_on_a_short_round():
    """The gate fires BEFORE any decode-matrix build: a short round must not
    pollute the cache or its hit/miss counters."""
    spec = CodeSpec(n=5, r=3, k=6, deg_f=1)
    cache = DecodeCache(spec)
    with pytest.raises(TimeoutError):
        cache.from_on_time(_short_mask(spec))
    assert len(cache) == 0 and cache.misses == 0 and cache.hits == 0
