"""Unit + property tests for the Lagrange coding scheme (paper Sec. 3.1/4.1)."""

import numpy as np
import pytest
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.core import lagrange as lcc


def test_kstar_formulas():
    # Paper Sec. 6.1: n=15, r=10, k=50, deg f = 2 -> K* = 99 (Lagrange branch)
    assert lcc.recovery_threshold(15, 10, 50, 2) == 99
    # Paper Sec. 6.2 (EC2): k in {120,100,50}, deg f = 1 -> K* = 50 for k=50
    assert lcc.recovery_threshold(15, 10, 50, 1) == 50
    # Sec. 3.1 worked examples: n=3, r=2, k=2, deg=2 -> nr=6 >= 3, K* = 3
    assert lcc.recovery_threshold(3, 2, 2, 2) == 3
    # Repetition example: n=3, r=2, k=4, deg=2 -> nr=6 < 7, K* = 6 - 1 + 1 = 6
    spec = lcc.CodeSpec(3, 2, 4, 2)
    assert spec.mode == "repetition"
    assert spec.recovery_threshold == 6


def test_generator_systematic_structure_repetition():
    spec = lcc.CodeSpec(3, 2, 4, 2)
    g = np.asarray(lcc.generator_matrix(spec))
    # every row is a unit vector; chunk v holds X_{v mod k}
    assert np.allclose(g.sum(axis=1), 1.0)
    for v in range(spec.nr):
        assert g[v, v % spec.k] == 1.0


def test_encode_decode_roundtrip_float_deg1():
    spec = lcc.CodeSpec(n=5, r=2, k=4, deg_f=1)
    assert spec.mode == "lagrange"
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(spec.k, 8, 6)), jnp.float32)
    g = lcc.generator_matrix(spec)
    xt = lcc.encode(g, x)
    # f = identity (deg 1): receive an arbitrary K*-subset
    received = np.array([1, 3, 6, 9])
    d = lcc.decode_matrix(spec, received)
    out = lcc.decode(d, xt[jnp.asarray(received)])
    np.testing.assert_allclose(np.asarray(out), np.asarray(x), rtol=2e-4, atol=2e-4)


def test_decode_deg2_quadratic_function():
    # f(X) = X * X (elementwise square) has total degree 2
    spec = lcc.CodeSpec(n=6, r=2, k=4, deg_f=2)
    assert spec.recovery_threshold == 7
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(spec.k, 5)), jnp.float64)
    g = lcc.generator_matrix(spec, jnp.float64)
    xt = lcc.encode(g, x)
    fx_tilde = xt * xt
    received = np.array([0, 2, 3, 5, 7, 8, 11])
    d = lcc.decode_matrix(spec, received, jnp.float64)
    out = lcc.decode(d, fx_tilde[jnp.asarray(received)])
    np.testing.assert_allclose(np.asarray(out), np.asarray(x * x), rtol=1e-5, atol=1e-6)


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(3, 8),
    r=st.integers(1, 3),
    k=st.integers(2, 6),
    deg_f=st.integers(1, 3),
    seed=st.integers(0, 2**31 - 1),
)
def test_mds_property_exact_modp(n, r, k, deg_f, seed):
    """ANY K*-subset decodes exactly over GF(p) — the MDS property (Defn 4.1).

    Uses f(X) = X^deg elementwise, whose total degree is deg_f, over the exact
    mod-p path, so the check is bit-exact for arbitrary parameters.
    """
    spec = lcc.CodeSpec(n, r, k, deg_f)
    kstar = spec.recovery_threshold
    if kstar > spec.nr:
        return  # infeasible code; nothing to assert
    rng = np.random.default_rng(seed)
    x = rng.integers(0, lcc.FIELD_P, size=(k, 3), dtype=np.int64)
    g = lcc.generator_matrix_modp(spec)
    xt = lcc.matmul_modp(g, x)
    # worker-side evaluation: elementwise x^deg mod p
    fx = xt.copy()
    for _ in range(deg_f - 1):
        fx = (fx * xt) % lcc.FIELD_P
    want = x.copy()
    for _ in range(deg_f - 1):
        want = (want * x) % lcc.FIELD_P

    received = rng.choice(spec.nr, size=kstar, replace=False)
    received.sort()
    if spec.mode == "repetition":
        d = lcc.decode_matrix_modp(spec, received)
        got = lcc.matmul_modp(d, fx[received])
    else:
        d = lcc.decode_matrix_modp(spec, received)
        got = lcc.matmul_modp(d, fx[received])
    np.testing.assert_array_equal(got, want)


@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(2, 6),
    r=st.integers(1, 4),
    k=st.integers(2, 8),
    seed=st.integers(0, 2**31 - 1),
)
def test_repetition_any_kstar_subset_covers_all_chunks(n, r, k, seed):
    """K* = nr - floor(nr/k) + 1 guarantees every chunk has an on-time copy."""
    spec = lcc.CodeSpec(n, r, k, deg_f=10_000)  # force repetition branch
    if spec.mode != "repetition" or spec.recovery_threshold > spec.nr:
        return
    rng = np.random.default_rng(seed)
    received = rng.choice(spec.nr, size=spec.recovery_threshold, replace=False)
    src = received % k
    assert set(src.tolist()) == set(range(k))
    # and the decode matrix therefore exists
    d = np.asarray(lcc.decode_matrix(spec, np.sort(received)))
    assert d.shape == (k, spec.recovery_threshold)
    np.testing.assert_allclose(d.sum(axis=1), 1.0)


def test_decode_matrix_validates_input():
    spec = lcc.CodeSpec(5, 2, 4, 1)
    with pytest.raises(ValueError):
        lcc.decode_matrix(spec, [0, 1])  # wrong count
    with pytest.raises(ValueError):
        lcc.decode_matrix(spec, [0, 0, 1, 2])  # duplicates


def test_conditioning_paper_scale_deg1():
    """Float decode at the paper's EC2 scale (k=50, deg 1, K*=50) stays accurate
    for a contiguous received set in float64."""
    spec = lcc.CodeSpec(15, 10, 50, 1)
    assert spec.recovery_threshold == 50
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.normal(size=(spec.k, 4)), jnp.float64)
    g = lcc.generator_matrix(spec, jnp.float64)
    xt = lcc.encode(g, x)
    received = np.arange(0, 150, 3)  # every 3rd chunk — spread subset
    d = lcc.decode_matrix(spec, received, jnp.float64)
    out = lcc.decode(d, xt[jnp.asarray(received)])
    np.testing.assert_allclose(np.asarray(out), np.asarray(x), rtol=1e-6, atol=1e-6)
