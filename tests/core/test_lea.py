"""Tests for the EA allocator: eq. (7)/(8), Lemma 4.4/4.5, estimator."""

import itertools
import math

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import lea
from repro.core.lea import EstimatorState, LoadParams


def _paper_sim_lp() -> LoadParams:
    # Sec. 6.1: n=15, r=10, k=50, deg=2, d=1, mu=(10,3) -> K*=99, lg=10, lb=3
    return LoadParams(n=15, kstar=99, ell_g=10, ell_b=3)


def test_success_prob_dp_matches_bruteforce_paper_params():
    lp = _paper_sim_lp()
    rng = np.random.default_rng(0)
    p = np.sort(rng.uniform(0.05, 0.95, size=lp.n))[::-1].copy()
    probs = np.asarray(lea.success_prob_all_prefixes(jnp.asarray(p), lp))
    # brute force only feasible for small prefixes; compare where 2^i <= 2^15
    for i in range(1, lp.n + 1):
        want = lea.success_prob_bruteforce(jnp.asarray(p), lp, i)
        np.testing.assert_allclose(probs[i - 1], want, rtol=1e-5, atol=1e-6)


@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(2, 10),
    seed=st.integers(0, 2**31 - 1),
    kstar_frac=st.floats(0.3, 1.0),
)
def test_success_prob_dp_matches_bruteforce_random(n, seed, kstar_frac):
    rng = np.random.default_rng(seed)
    ell_b = int(rng.integers(1, 4))
    ell_g = ell_b + int(rng.integers(1, 8))
    kstar = max(n * ell_b + 1, int(kstar_frac * n * ell_g))  # nontrivial region
    if kstar > n * ell_g:
        kstar = n * ell_g  # keep feasible at i~ = n
    lp = LoadParams(n=n, kstar=kstar, ell_g=ell_g, ell_b=ell_b)
    p = np.sort(rng.uniform(0.0, 1.0, size=n))[::-1].copy()
    probs = np.asarray(lea.success_prob_all_prefixes(jnp.asarray(p), lp))
    for i in range(1, n + 1):
        want = lea.success_prob_bruteforce(jnp.asarray(p), lp, i)
        np.testing.assert_allclose(probs[i - 1], want, rtol=1e-5, atol=1e-6)


def test_allocate_matches_exhaustive_search_over_all_subsets():
    """LEA's linear search (Lemma 4.5) equals the 2^n exhaustive optimum."""
    rng = np.random.default_rng(42)
    n, ell_g, ell_b = 8, 5, 2
    for trial in range(5):
        kstar = int(rng.integers(n * ell_b + 1, n * ell_g + 1))
        lp = LoadParams(n=n, kstar=kstar, ell_g=ell_g, ell_b=ell_b)
        p = rng.uniform(0.05, 0.95, size=n)

        # exhaustive: every subset G_g gets ell_g, complement ell_b
        best = 0.0
        for size in range(0, n + 1):
            for gg in itertools.combinations(range(n), size):
                a = math.ceil((kstar - (n - size) * ell_b) / ell_g)
                if a > size:
                    continue
                prob = 0.0
                for good_mask in itertools.product([0, 1], repeat=size):
                    if sum(good_mask) >= max(a, 0):
                        q = 1.0
                        for idx, gm in zip(gg, good_mask):
                            q *= p[idx] if gm else 1 - p[idx]
                        prob += q
                best = max(best, prob)

        loads, i_star = lea.allocate(jnp.asarray(p), lp)
        probs = np.asarray(lea.success_prob_all_prefixes(
            jnp.asarray(np.sort(p)[::-1].copy()), lp))
        got = probs[int(i_star) - 1]
        np.testing.assert_allclose(got, best, rtol=1e-5, atol=1e-6)
        # allocation consistency: exactly i_star workers at ell_g, the top ones
        loads = np.asarray(loads)
        assert (loads == ell_g).sum() == int(i_star)
        top = np.argsort(-p)[: int(i_star)]
        assert set(np.nonzero(loads == ell_g)[0].tolist()) == set(top.tolist())


def test_lemma45_greedy_prefix_beats_any_same_size_subset():
    """For fixed |G_g|, taking the largest-p workers maximizes success prob."""
    rng = np.random.default_rng(7)
    n, ell_g, ell_b = 7, 4, 1
    kstar = 17
    lp = LoadParams(n=n, kstar=kstar, ell_g=ell_g, ell_b=ell_b)
    p = np.sort(rng.uniform(0.1, 0.9, size=n))[::-1].copy()

    def subset_prob(gg):
        size = len(gg)
        a = math.ceil((kstar - (n - size) * ell_b) / ell_g)
        if a > size:
            return 0.0
        prob = 0.0
        for good_mask in itertools.product([0, 1], repeat=size):
            if sum(good_mask) >= max(a, 0):
                q = 1.0
                for idx, gm in zip(gg, good_mask):
                    q *= p[idx] if gm else 1 - p[idx]
                prob += q
        return prob

    for size in range(1, n + 1):
        greedy = subset_prob(tuple(range(size)))
        for gg in itertools.combinations(range(n), size):
            assert greedy >= subset_prob(gg) - 1e-9


def test_estimator_counts_and_first_round_semantics():
    est = lea.init_estimator(3)
    s1 = jnp.asarray([1, 0, 1])
    est = lea.update_estimator(est, s1)
    assert np.all(np.asarray(est.counts) == 0)  # first obs: no transition
    s2 = jnp.asarray([1, 1, 0])
    est = lea.update_estimator(est, s2)
    c = np.asarray(est.counts)
    np.testing.assert_array_equal(c[0], [1, 0, 0, 0])  # g->g
    np.testing.assert_array_equal(c[1], [0, 0, 1, 0])  # b->g
    np.testing.assert_array_equal(c[2], [0, 1, 0, 0])  # g->b


def test_estimator_converges_to_true_transitions():
    """SLLN check underpinning Lemma 5.2: counts -> true transition probs."""
    from repro.core import markov

    p_gg = jnp.asarray([0.8, 0.9, 0.6])
    p_bb = jnp.asarray([0.7, 0.6, 0.533])
    traj = markov.sample_trajectory(jax.random.PRNGKey(0), p_gg, p_bb, 20000)

    def body(est, s):
        return lea.update_estimator(est, s), None

    est, _ = jax.lax.scan(body, lea.init_estimator(3), traj)
    e_gg, e_bb = lea.estimated_transitions(est)
    np.testing.assert_allclose(np.asarray(e_gg), np.asarray(p_gg), atol=0.03)
    np.testing.assert_allclose(np.asarray(e_bb), np.asarray(p_bb), atol=0.03)


def test_round_success_thresholds():
    lp = LoadParams(n=3, kstar=10, ell_g=5, ell_b=2)
    mu_g, mu_b, d = 5.0, 2.0, 1.0
    # all good, loads (5,5,2): received 12 >= 10
    ok = lea.round_success(jnp.asarray([5, 5, 2]), jnp.asarray([1, 1, 1]), lp, mu_g, mu_b, d)
    assert bool(ok)
    # one good worker at ell_g late (bad state): 5/2 > 1 -> only 5+2 received
    ok = lea.round_success(jnp.asarray([5, 5, 2]), jnp.asarray([1, 0, 1]), lp, mu_g, mu_b, d)
    assert not bool(ok)
    # bad-state workers always deliver ell_b on time
    ok = lea.round_success(jnp.asarray([2, 2, 2]), jnp.asarray([0, 0, 0]), lp, mu_g, mu_b, 1.0)
    assert not bool(ok)  # 6 < 10, on time but insufficient


def test_loadparams_validation():
    with pytest.raises(ValueError):
        LoadParams(n=4, kstar=10, ell_g=2, ell_b=2)
