"""Device-resident exact GF(p) coding layer vs the numpy ``*_modp`` oracle.

Every comparison is exact integer equality (array_equal): the device path
(``repro.kernels.gf`` through ``core.lagrange``/``core.coded_ops``) must be
bit-identical to the numpy ``matmul_modp``/``decode_matrix_modp`` pipeline —
including for erasure patterns sampled from engine ``rollout()``
trajectories, the acceptance bar of the subsystem.
"""

import numpy as np
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.core import lagrange as lcc
from repro.core import throughput
from repro.core.coded_ops import (ModpDecodeCache, chunk_on_time,
                                  coded_linear_gradient_modp,
                                  coded_matmul_exact, encode_dataset_modp)
from repro.core.lea import LoadParams

P = lcc.FIELD_P


def _np_pipeline(spec, xt_np, w_np, on_time):
    """The numpy oracle round: shard matmul -> first-K* gather -> decode."""
    kstar = spec.recovery_threshold
    rows = xt_np.shape[1]
    res = lcc.matmul_modp(xt_np.reshape(spec.nr * rows, -1), w_np.reshape(w_np.shape[0], -1))
    res = res.reshape(spec.nr, rows, -1)
    rec = np.nonzero(on_time)[0][:kstar]
    d = lcc.decode_matrix_modp(spec, rec)
    return lcc.matmul_modp(d, res[rec].reshape(kstar, -1)).reshape(
        (spec.k, rows) + ((w_np.shape[1],) if w_np.ndim == 2 else ())
    )


@settings(max_examples=12, deadline=None)
@given(
    n=st.integers(3, 8),
    r=st.integers(1, 3),
    k=st.integers(2, 6),
    deg_f=st.integers(1, 3),
)
def test_generator_and_decode_matrix_device_bit_equal_numpy(n, r, k, deg_f):
    spec = lcc.CodeSpec(n, r, k, deg_f)
    kstar = spec.recovery_threshold
    if kstar > spec.nr:
        return  # infeasible code
    np.testing.assert_array_equal(
        np.asarray(lcc.generator_matrix_modp_device(spec), np.int64),
        lcc.generator_matrix_modp(spec),
    )
    rng = np.random.default_rng(n * 100 + r * 10 + k + deg_f)
    for _ in range(3):
        received = np.sort(rng.choice(spec.nr, size=kstar, replace=False))
        np.testing.assert_array_equal(
            np.asarray(
                lcc.decode_matrix_modp_device(spec, jnp.asarray(received, jnp.int32)),
                np.int64,
            ),
            lcc.decode_matrix_modp(spec, received),
        )


def test_decode_matrix_device_batched_over_patterns():
    spec = lcc.CodeSpec(5, 2, 4, 1)
    kstar = spec.recovery_threshold
    rng = np.random.default_rng(0)
    received = np.stack(
        [np.sort(rng.choice(spec.nr, size=kstar, replace=False)) for _ in range(6)]
    )
    got = np.asarray(
        lcc.decode_matrix_modp_device(spec, jnp.asarray(received, jnp.int32)),
        np.int64,
    )
    assert got.shape == (6, spec.k, kstar)
    for i in range(6):
        np.testing.assert_array_equal(got[i], lcc.decode_matrix_modp(spec, received[i]))


@settings(max_examples=12, deadline=None)
@given(
    n=st.integers(3, 7),
    r=st.integers(1, 3),
    k=st.integers(2, 6),
    rows=st.integers(1, 4),
    cols=st.integers(1, 5),
    seed=st.integers(0, 2**31 - 1),
)
def test_encode_erase_decode_roundtrip_vs_numpy(n, r, k, rows, cols, seed):
    """encode -> random erasure -> decode == the numpy pipeline AND the raw
    data (deg 1 round-trip), over random shapes and patterns, with the
    0 / p-1 boundary residues spliced into the data."""
    spec = lcc.CodeSpec(n, r, k, deg_f=1)
    kstar = spec.recovery_threshold
    if kstar > spec.nr:
        return
    rng = np.random.default_rng(seed)
    x = rng.integers(0, P, size=(k, rows, cols), dtype=np.int64)
    x.reshape(-1)[: 4] = [0, P - 1, 1, P - 2][:x.size]        # boundary residues
    w = rng.integers(0, P, size=(cols,), dtype=np.int64)
    w[:1] = P - 1

    coded = encode_dataset_modp(spec, jnp.asarray(x, jnp.int32))
    xt_np = lcc.matmul_modp(lcc.generator_matrix_modp(spec), x.reshape(k, -1))
    np.testing.assert_array_equal(
        np.asarray(coded.x_tilde, np.int64).reshape(spec.nr, -1), xt_np)

    # a random K*-subset survives
    on_time = np.zeros(spec.nr, bool)
    on_time[rng.choice(spec.nr, size=kstar, replace=False)] = True
    out, ok = coded_matmul_exact(coded, jnp.asarray(w, jnp.int32), jnp.asarray(on_time))
    assert bool(ok)
    want = _np_pipeline(spec, xt_np.reshape(spec.nr, rows, cols), w, on_time)
    np.testing.assert_array_equal(np.asarray(out, np.int64), want)
    # deg-1 MDS round-trip: the decode recovers f(X_j) = X_j @ w exactly
    np.testing.assert_array_equal(
        np.asarray(out, np.int64),
        lcc.matmul_modp(x.reshape(-1, cols), w.reshape(-1, 1)).reshape(k, rows),
    )


def test_exact_decode_repetition_branch_vs_numpy():
    spec = lcc.CodeSpec(3, 2, 4, 2)        # nr=6 < k*deg-1: repetition, K*=6
    assert spec.mode == "repetition"
    rng = np.random.default_rng(5)
    x = rng.integers(0, P, size=(spec.k, 2, 3), dtype=np.int64)
    w = rng.integers(0, P, size=(3,), dtype=np.int64)
    coded = encode_dataset_modp(spec, jnp.asarray(x, jnp.int32))
    on_time = np.ones(spec.nr, bool)
    out, ok = coded_matmul_exact(coded, jnp.asarray(w, jnp.int32), jnp.asarray(on_time))
    assert bool(ok)
    xt_np = np.asarray(coded.x_tilde, np.int64)
    want = _np_pipeline(spec, xt_np, w, on_time)
    np.testing.assert_array_equal(np.asarray(out, np.int64), want)


def test_short_round_reports_not_ok():
    spec = lcc.CodeSpec(5, 2, 4, 1)
    coded = encode_dataset_modp(
        spec, jnp.asarray(np.arange(4 * 2 * 3).reshape(4, 2, 3), jnp.int32))
    on_time = np.zeros(spec.nr, bool)
    on_time[: spec.recovery_threshold - 1] = True          # one short of K*
    _, ok = coded_matmul_exact(
        coded, jnp.asarray(np.ones(3), jnp.int32), jnp.asarray(on_time))
    assert not bool(ok)


def test_modp_cache_from_on_time_raises_on_short_pattern():
    """The eager cache path mirrors coded_matmul's TimeoutError convention
    instead of silently building a truncated decode matrix."""
    spec = lcc.CodeSpec(5, 2, 4, 1)
    cache = ModpDecodeCache(spec)
    on_time = np.zeros(spec.nr, bool)
    on_time[: spec.recovery_threshold - 1] = True          # one short of K*
    try:
        cache.from_on_time(on_time)
    except TimeoutError as e:
        assert f"K*={spec.recovery_threshold}" in str(e)
    else:  # pragma: no cover
        raise AssertionError("expected TimeoutError")
    assert len(cache) == 0 and cache.misses == 0            # nothing memoised
    # exactly K* on time still works
    on_time[spec.recovery_threshold - 1] = True
    received, dmat = cache.from_on_time(on_time)
    np.testing.assert_array_equal(
        np.asarray(dmat, np.int64), lcc.decode_matrix_modp(spec, received))


def test_exact_round_on_engine_rollout_patterns():
    """The acceptance bar: coded_matmul_exact == numpy pipeline for every
    feasible erasure pattern produced by an engine rollout's Markov
    trajectories (both strategy columns), via chunk_on_time."""
    spec = lcc.CodeSpec(6, 3, 5, 1)
    kstar = spec.recovery_threshold
    lp = LoadParams(n=6, kstar=kstar, ell_g=3, ell_b=1)
    mu_g, mu_b, deadline = 3.0, 1.0, 1.0
    rng = np.random.default_rng(2)
    x = rng.integers(0, P, size=(spec.k, 4, 7), dtype=np.int64)
    x[0, 0, 0], x[1, 1, 1] = 0, P - 1
    w = rng.integers(0, P, size=(7,), dtype=np.int64)
    coded = encode_dataset_modp(spec, jnp.asarray(x, jnp.int32))
    xt_np = np.asarray(coded.x_tilde, np.int64)

    states, loads, feasible = throughput.rollout(
        jax.random.PRNGKey(0), lp, jnp.full((6,), 0.8), jnp.full((6,), 0.7),
        30, strategies=("lea", "static"),
    )
    masks = np.asarray(chunk_on_time(states, loads, mu_g, mu_b, deadline, spec.r))
    succ = np.asarray(throughput.score_rollout(
        states, loads, feasible, lp, mu_g, mu_b, deadline))

    fn = jax.jit(lambda m: coded_matmul_exact(coded, jnp.asarray(w, jnp.int32), m))
    cache = ModpDecodeCache(spec)
    checked = 0
    for s in range(masks.shape[0]):
        for m in range(masks.shape[1]):
            on = masks[s, m]
            # chunk masks and engine scoring agree on round success
            assert bool(succ[m, s]) == (on.sum() >= kstar and bool(feasible[s, m]))
            if on.sum() < kstar:
                continue
            out, ok = fn(jnp.asarray(on))
            assert bool(ok)
            want = _np_pipeline(spec, xt_np, w, on)
            np.testing.assert_array_equal(np.asarray(out, np.int64), want)
            # the memoised decode matrix is the same numpy matrix
            received, dmat = cache.from_on_time(on)
            np.testing.assert_array_equal(
                np.asarray(dmat, np.int64), lcc.decode_matrix_modp(spec, received))
            checked += 1
    assert checked > 10
    # discrete worker states ==> patterns recur ==> the cache actually hits
    assert cache.hits > 0 and len(cache) == cache.misses


def test_chunk_on_time_broadcasts_and_prefix_rule():
    # worker 0 good (all 3 chunks), worker 1 bad with load 1 (<= ell_b: first
    # chunk only), worker 2 bad with load 3 (misses deadline: nothing)
    states = jnp.asarray([[1, 0, 0]])
    loads = jnp.asarray([[3, 1, 3]])
    mask = np.asarray(chunk_on_time(states, loads, 3.0, 1.0, 1.0, r=3))
    np.testing.assert_array_equal(
        mask[0], [True, True, True, True, False, False, False, False, False])


# ---------------------------------------------------------------------------
# exact deg-2 gradient: coded_linear_gradient_modp vs the numpy oracle
# ---------------------------------------------------------------------------

def _np_gradient_oracle(spec, xt_np, yt_np, w_np, on_time):
    """Numpy replication: per-chunk X~^T(X~ w - y~), gather, decode, sum."""
    kstar = spec.recovery_threshold
    w2 = w_np.reshape(w_np.shape[0], -1)
    grads = []
    for v in range(spec.nr):
        resid = (lcc.matmul_modp(xt_np[v], w2) - yt_np[v][:, None]) % P
        grads.append(lcc.matmul_modp(xt_np[v].T, resid))
    grads = np.stack(grads)                               # (nr, cols, d)
    rec = np.nonzero(on_time)[0][:kstar]
    d = lcc.decode_matrix_modp(spec, rec)
    per_chunk = lcc.matmul_modp(d, grads[rec].reshape(kstar, -1)).reshape(
        (spec.k,) + grads.shape[1:]
    )
    total = per_chunk.sum(axis=0) % P
    return total[:, 0] if w_np.ndim == 1 else total


@settings(max_examples=10, deadline=None)
@given(
    n=st.integers(3, 7),
    r=st.integers(2, 3),
    rows=st.integers(1, 5),
    cols=st.integers(1, 4),
    seed=st.integers(0, 2**31 - 1),
)
def test_coded_linear_gradient_modp_bit_equal_numpy(n, r, rows, cols, seed):
    rng = np.random.default_rng(seed)
    k = max(2, (n * r) // 3)
    spec = lcc.CodeSpec(n, r, k, deg_f=2)
    if spec.mode != "lagrange":
        return
    x = rng.integers(0, P, size=(k, rows, cols), dtype=np.int64)
    y = rng.integers(0, P, size=(k, rows), dtype=np.int64)
    w = rng.integers(0, P, size=(cols,), dtype=np.int64)
    coded = encode_dataset_modp(spec, jnp.asarray(x, jnp.int32),
                                jnp.asarray(y, jnp.int32))
    xt_np = np.asarray(coded.x_tilde, np.int64)
    yt_np = np.asarray(coded.y_tilde, np.int64)
    on_time = np.zeros(spec.nr, bool)
    extra = int(rng.integers(0, spec.nr - spec.recovery_threshold + 1))
    on_time[rng.choice(spec.nr, spec.recovery_threshold + extra,
                       replace=False)] = True
    got, ok = jax.jit(
        lambda m: coded_linear_gradient_modp(coded, jnp.asarray(w, jnp.int32), m)
    )(jnp.asarray(on_time))
    assert bool(ok)
    want = _np_gradient_oracle(spec, xt_np, yt_np, w, on_time)
    np.testing.assert_array_equal(np.asarray(got, np.int64), want)


def test_coded_linear_gradient_modp_matrix_targets_and_validation():
    import pytest

    rng = np.random.default_rng(3)
    spec = lcc.CodeSpec(5, 3, 4, deg_f=2)
    x = rng.integers(0, P, size=(4, 3, 2), dtype=np.int64)
    y = rng.integers(0, P, size=(4, 3), dtype=np.int64)
    w2 = rng.integers(0, P, size=(2, 3), dtype=np.int64)   # (cols, d) targets
    coded = encode_dataset_modp(spec, jnp.asarray(x, jnp.int32),
                                jnp.asarray(y, jnp.int32))
    on_time = np.ones(spec.nr, bool)
    got, ok = coded_linear_gradient_modp(coded, jnp.asarray(w2, jnp.int32),
                                         jnp.asarray(on_time))
    assert bool(ok) and got.shape == (2, 3)
    want = _np_gradient_oracle(
        spec, np.asarray(coded.x_tilde, np.int64),
        np.asarray(coded.y_tilde, np.int64), w2, on_time,
    )
    np.testing.assert_array_equal(np.asarray(got, np.int64), want)

    # short pattern -> ok False, targetless/odd-degree datasets raise
    short = np.zeros(spec.nr, bool)
    short[: spec.recovery_threshold - 1] = True
    _, ok = coded_linear_gradient_modp(coded, jnp.asarray(w2, jnp.int32),
                                       jnp.asarray(short))
    assert not bool(ok)
    no_targets = encode_dataset_modp(spec, jnp.asarray(x, jnp.int32))
    with pytest.raises(ValueError, match="without targets"):
        coded_linear_gradient_modp(no_targets, jnp.asarray(w2, jnp.int32),
                                   jnp.asarray(on_time))
    spec1 = lcc.CodeSpec(5, 3, 4, deg_f=1)
    coded1 = encode_dataset_modp(spec1, jnp.asarray(x, jnp.int32),
                                 jnp.asarray(y, jnp.int32))
    with pytest.raises(ValueError, match="degree-2"):
        coded_linear_gradient_modp(coded1, jnp.asarray(w2, jnp.int32),
                                   jnp.asarray(on_time))
