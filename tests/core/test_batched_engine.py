"""Property tests for the batched Monte-Carlo engine (PR: batched simulation
engine + Pallas Poisson-binomial allocator kernel + device decode path).

Covers the ISSUE's required properties:
  * batched ``allocate`` over a (B, n) probability batch == per-row allocate;
  * Pallas ``poisson_binomial`` kernel (interpret mode) == the lax.scan DP
    oracle == ``success_prob_bruteforce`` for n <= 12;
  * engine internals: multi-strategy single computation == per-strategy runs,
    vmapped sweep == looped runs, explicit failed-round accounting for
    cap-exhausted static resampling;
  * device-resident decode == host decode (lagrange + repetition branches).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import lagrange as lcc
from repro.core import lea, throughput
from repro.core.coded_ops import (DecodeCache, coded_linear_gradient,
                                  coded_linear_gradient_device, coded_matmul,
                                  coded_matmul_device, encode_dataset)
from repro.core.lea import LoadParams
from repro.kernels.poisson_binomial import (success_tails_pallas,
                                            success_tails_ref)


def _random_lp(rng, n) -> LoadParams:
    ell_b = int(rng.integers(1, 4))
    ell_g = ell_b + int(rng.integers(1, 8))
    kstar = int(rng.integers(n * ell_b + 1, n * ell_g + 1))
    return LoadParams(n=n, kstar=kstar, ell_g=ell_g, ell_b=ell_b)


# ---------------------------------------------------------------------------
# batched allocate == per-row allocate
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(n=st.integers(2, 20), b=st.integers(1, 12), seed=st.integers(0, 2**31 - 1))
def test_batched_allocate_matches_per_row(n, b, seed):
    rng = np.random.default_rng(seed)
    lp = _random_lp(rng, n)
    p = jnp.asarray(rng.uniform(0.0, 1.0, size=(b, n)), jnp.float32)
    loads_b, istar_b = lea.allocate(p, lp)
    assert loads_b.shape == (b, n) and istar_b.shape == (b,)
    for row in range(b):
        loads_r, istar_r = lea.allocate(p[row], lp)
        np.testing.assert_array_equal(np.asarray(loads_b[row]), np.asarray(loads_r))
        assert int(istar_b[row]) == int(istar_r)


def test_batched_allocate_with_ties_matches_per_row():
    """Stable tie-breaking (constant and duplicated p) must agree per row."""
    lp = LoadParams(n=6, kstar=14, ell_g=4, ell_b=2)
    p = jnp.asarray(
        [[0.5] * 6, [0.9, 0.5, 0.9, 0.5, 0.9, 0.5], [0.0] * 6, [1.0] * 6],
        jnp.float32,
    )
    loads_b, istar_b = lea.allocate(p, lp)
    for row in range(p.shape[0]):
        loads_r, istar_r = lea.allocate(p[row], lp)
        np.testing.assert_array_equal(np.asarray(loads_b[row]), np.asarray(loads_r))
        assert int(istar_b[row]) == int(istar_r)


def test_allocate_large_n_sort_path_matches_pairwise():
    """n above the pairwise-rank cutoff uses XLA sorts; both paths agree."""
    rng = np.random.default_rng(0)
    n = lea._PAIRWISE_RANK_MAX_N + 8
    lp = _random_lp(rng, n)
    p = jnp.asarray(rng.uniform(0, 1, size=(3, n)), jnp.float32)
    ranks = lea._ranks_descending(p)
    order = jnp.argsort(-p, axis=-1)
    np.testing.assert_array_equal(
        np.asarray(ranks), np.asarray(jnp.argsort(order, axis=-1))
    )
    np.testing.assert_array_equal(
        np.asarray(lea._take_by_rank(p, ranks)),
        np.asarray(jnp.take_along_axis(p, order, axis=-1)),
    )


# ---------------------------------------------------------------------------
# Pallas kernel (interpret) == lax.scan DP oracle == bruteforce (n <= 12)
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(n=st.integers(2, 12), b=st.integers(1, 8), seed=st.integers(0, 2**31 - 1))
def test_pallas_kernel_matches_ref_and_bruteforce(n, b, seed):
    rng = np.random.default_rng(seed)
    lp = _random_lp(rng, n)
    w = lea.prefix_thresholds(lp)
    p = np.sort(rng.uniform(0.0, 1.0, size=(b, n)), axis=-1)[:, ::-1].copy()
    pj = jnp.asarray(p, jnp.float32)
    ref = np.asarray(success_tails_ref(pj, w))
    pal = np.asarray(success_tails_pallas(pj, tuple(int(v) for v in w), interpret=True))
    # reduction trees differ between the padded-VMEM kernel and the ref scan,
    # so agreement is to float32 round-off, not bitwise
    np.testing.assert_allclose(pal, ref, rtol=1e-6, atol=1e-7)
    for row in range(b):
        for i in range(1, n + 1):
            want = lea.success_prob_bruteforce(pj[row], lp, i)
            np.testing.assert_allclose(ref[row, i - 1], want, rtol=1e-5, atol=1e-6)


def test_pallas_kernel_batch_tiling_paths():
    """Batches straddling the block size tile correctly (padding inert)."""
    rng = np.random.default_rng(3)
    lp = LoadParams(n=15, kstar=99, ell_g=10, ell_b=3)
    w = tuple(int(v) for v in lea.prefix_thresholds(lp))
    for b in (1, 7, 256, 300):
        p = jnp.asarray(
            np.sort(rng.uniform(0, 1, size=(b, 15)), axis=-1)[:, ::-1].copy(),
            jnp.float32,
        )
        pal = success_tails_pallas(p, w, block_b=256, interpret=True)
        ref = success_tails_ref(p, np.asarray(w))
        np.testing.assert_allclose(np.asarray(pal), np.asarray(ref),
                                   rtol=1e-6, atol=1e-7)


# ---------------------------------------------------------------------------
# engine: fused strategies / vmapped sweep / explicit static failure
# ---------------------------------------------------------------------------

LP = LoadParams(n=15, kstar=99, ell_g=10, ell_b=3)


def test_simulate_strategies_columns_match_single_strategy_runs():
    key = jax.random.PRNGKey(7)
    args = (jnp.full((15,), 0.8), jnp.full((15,), 0.7), 10.0, 3.0, 1.0, 500)
    strategies = ("lea", "static", "static_equal", "static_single", "oracle")
    fused = throughput.simulate_strategies(key, LP, *args, strategies=strategies)
    for j, s in enumerate(strategies):
        single = throughput.simulate(key, s, LP, *args)
        np.testing.assert_array_equal(np.asarray(fused[:, j]), np.asarray(single))


def test_sweep_matches_looped_simulate_strategies():
    scen = [(0.8, 0.8), (0.9, 0.6)]
    seeds = 3
    rows = [(i, pgg, pbb, s) for i, (pgg, pbb) in enumerate(scen) for s in range(seeds)]
    keys = jnp.stack([jax.random.PRNGKey(i * 100 + s) for i, _, _, s in rows])
    pgg = jnp.stack([jnp.full((15,), p) for _, p, _, _ in rows])
    pbb = jnp.stack([jnp.full((15,), p) for _, _, p, _ in rows])
    swept = throughput.sweep(keys, LP, pgg, pbb, 10.0, 3.0, 1.0, 400)
    for r in range(len(rows)):
        one = throughput.simulate_strategies(
            keys[r], LP, pgg[r], pbb[r], 10.0, 3.0, 1.0, 400
        )
        np.testing.assert_array_equal(np.asarray(swept[r]), np.asarray(one))


def test_static_cap_exhaustion_counts_as_failed_round():
    """pi_g = 0 makes every draw all-ell_b (sum < K*): the resampling cap is
    exhausted and the round must be explicitly infeasible and unsuccessful."""
    keys = jax.random.split(jax.random.PRNGKey(0), 16)
    loads, feasible = throughput._static_loads_batch(
        keys, jnp.zeros((15,)), LP.kstar, LP.ell_g, LP.ell_b
    )
    assert not bool(jnp.any(feasible))
    np.testing.assert_array_equal(np.asarray(loads), np.full((16, 15), LP.ell_b))
    # and end-to-end: a scenario pinned to the bad state never succeeds but
    # also never crashes or mis-scores
    succ = throughput.simulate(
        jax.random.PRNGKey(1), "static", LP,
        jnp.full((15,), 0.01), jnp.full((15,), 0.99), 10.0, 3.0, 1.0, 64,
    )
    assert not bool(jnp.any(succ))


def test_lea_p_good_trajectory_matches_sequential_estimator():
    """The cumsum estimator replay equals sequential update_estimator calls."""
    key = jax.random.PRNGKey(5)
    states = jax.random.bernoulli(key, 0.6, (50, 4)).astype(jnp.int32)
    p_traj = throughput._lea_p_good_trajectory(states)
    est = lea.init_estimator(4)
    for m in range(50):
        want = jnp.where(
            est.seen_prev, lea.predicted_good_prob(est), jnp.full((4,), 0.5)
        )
        np.testing.assert_array_equal(np.asarray(p_traj[m]), np.asarray(want))
        est = lea.update_estimator(est, states[m])


# ---------------------------------------------------------------------------
# device-resident decode == host decode
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_device_decode_matrix_matches_host_lagrange(seed):
    rng = np.random.default_rng(seed)
    spec = lcc.CodeSpec(n=5, r=3, k=6, deg_f=1)
    received = np.sort(
        rng.choice(spec.nr, spec.recovery_threshold, replace=False)
    )
    host = np.asarray(lcc.decode_matrix(spec, received))
    dev = np.asarray(lcc.decode_matrix_jax(spec, jnp.asarray(received)))
    np.testing.assert_allclose(dev, host, rtol=2e-4, atol=2e-4)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_device_decode_matrix_matches_host_repetition(seed):
    rng = np.random.default_rng(seed)
    spec = lcc.CodeSpec(n=4, r=2, k=4, deg_f=10**9)
    assert spec.mode == "repetition"
    received = np.sort(
        rng.choice(spec.nr, spec.recovery_threshold, replace=False)
    )
    host = np.asarray(lcc.decode_matrix(spec, received))
    dev = np.asarray(lcc.decode_matrix_jax(spec, jnp.asarray(received)))
    np.testing.assert_array_equal(dev, host)


def test_coded_matmul_device_and_cache_match_eager():
    rng = np.random.default_rng(0)
    spec = lcc.CodeSpec(n=5, r=3, k=6, deg_f=1)
    x = jnp.asarray(rng.normal(size=(spec.k, 4, 3)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(3,)), jnp.float32)
    coded = encode_dataset(spec, x)
    cache = DecodeCache(spec)
    want = jnp.einsum("krc,c->kr", x, w)
    for trial in range(5):
        on_time = np.zeros(spec.nr, bool)
        on_time[rng.choice(spec.nr, spec.recovery_threshold + trial % 3,
                           replace=False)] = True
        eager = coded_matmul(coded, w, on_time)
        cached = coded_matmul(coded, w, on_time, cache=cache)
        dev, ok = coded_matmul_device(coded, w, jnp.asarray(on_time))
        assert bool(ok)
        np.testing.assert_array_equal(np.asarray(cached), np.asarray(eager))
        np.testing.assert_allclose(np.asarray(dev), np.asarray(eager),
                                   rtol=1e-3, atol=1e-4)
        np.testing.assert_allclose(np.asarray(dev), np.asarray(want),
                                   rtol=1e-3, atol=1e-3)
    assert cache.misses + cache.hits == 5 and cache.hits >= 0


def test_coded_matmul_device_flags_insufficient_results():
    rng = np.random.default_rng(1)
    spec = lcc.CodeSpec(n=5, r=3, k=6, deg_f=1)
    x = jnp.asarray(rng.normal(size=(spec.k, 2, 3)), jnp.float32)
    coded = encode_dataset(spec, x)
    on_time = np.zeros(spec.nr, bool)
    on_time[: spec.recovery_threshold - 1] = True
    _, ok = coded_matmul_device(coded, jnp.ones((3,), jnp.float32), jnp.asarray(on_time))
    assert not bool(ok)
    with pytest.raises(TimeoutError):
        coded_matmul(coded, jnp.ones((3,), jnp.float32), on_time)
    # the cache itself enforces the same convention for direct callers
    with pytest.raises(TimeoutError):
        DecodeCache(spec).from_on_time(on_time)


def test_coded_linear_gradient_device_matches_eager_and_jits():
    rng = np.random.default_rng(2)
    spec = lcc.CodeSpec(n=6, r=3, k=4, deg_f=2)
    x = jnp.asarray(rng.normal(size=(spec.k, 5, 3)), jnp.float32)
    y = jnp.asarray(rng.normal(size=(spec.k, 5)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(3,)), jnp.float32)
    coded = encode_dataset(spec, x, y)
    on_time = np.zeros(spec.nr, bool)
    on_time[rng.choice(spec.nr, spec.recovery_threshold, replace=False)] = True
    eager = coded_linear_gradient(coded, w, on_time)

    @jax.jit
    def round_fn(w, mask):
        return coded_linear_gradient_device(coded, w, mask)

    dev, ok = round_fn(w, jnp.asarray(on_time))
    assert bool(ok)
    scale = float(jnp.abs(eager).max())
    np.testing.assert_allclose(np.asarray(dev), np.asarray(eager),
                               rtol=1e-3, atol=1e-3 * max(scale, 1.0))
