"""repro.policies: registry resolution, estimator closed forms, bit-identity
of the registry-resolved ``lea``/``oracle`` with the pre-refactor engine,
non-stationary chain support, and the engine integration paths."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import policies
from repro.core import lea, markov, throughput
from repro.core.lea import LoadParams
from repro.policies import estimators
from repro.policies.api import Policy, PolicyContext

LP = LoadParams(n=15, kstar=99, ell_g=10, ell_b=3)


def _ctx(states, p_gg=None, p_bb=None, key=None):
    n = states.shape[1]
    p_gg = jnp.full((n,), 0.8) if p_gg is None else p_gg
    p_bb = jnp.full((n,), 0.7) if p_bb is None else p_bb
    row0 = (p_gg[0], p_bb[0]) if p_gg.ndim == 2 else (p_gg, p_bb)
    return PolicyContext(
        states=states, p_gg=p_gg, p_bb=p_bb,
        pi_g=markov.stationary_good_prob(*row0),
        key=jax.random.PRNGKey(0) if key is None else key,
    )


def _states(key=0, rounds=60, n=6, p=0.6):
    return jax.random.bernoulli(
        jax.random.PRNGKey(key), p, (rounds, n)
    ).astype(jnp.int32)


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

def test_builtin_policies_registered():
    names = policies.names()
    assert {"lea", "oracle", "lea_window64", "lea_window256", "lea_discount97",
            "thompson", "ucb"} <= set(names)
    cat = policies.catalogue()
    for n in names:
        assert n in cat
        assert policies.resolve(n).name == n


def test_dynamic_family_spellings_resolve_and_memoise():
    a = policies.resolve("lea_window48")
    assert a is policies.resolve("lea_window48")    # memoised instance
    assert "lea_window48" in policies.names()
    d = policies.resolve("lea_discount995")
    assert "0.995" in d.description
    with pytest.raises(KeyError):
        policies.resolve("lea_window0")
    with pytest.raises(KeyError):
        policies.resolve("no_such_policy")


def test_is_registered_rejects_out_of_range_dynamic_spellings():
    """Validation-time and resolve-time must agree: a spelling resolve would
    reject is not 'registered', so engines/scenarios fail with the clean
    ValueError instead of a KeyError mid-trace."""
    assert not policies.is_registered("lea_window0")
    assert not policies.is_registered("lea_discount0")
    assert policies.is_registered("lea_window1")
    assert policies.is_registered("lea_discount5")
    assert not throughput.strategy_known("lea_window0")


def test_discount_names_round_trip_through_dynamic_resolver():
    """discounted_lea's default name is the canonical lea_discount<D>
    spelling (D = decimal digits), so registering an instance and resolving
    its name dynamically can never disagree about gamma."""
    assert estimators.discounted_lea(0.995).name == "lea_discount995"
    assert estimators.discounted_lea(0.5).name == "lea_discount5"
    with pytest.raises(ValueError, match="no exact"):
        estimators.discounted_lea(1.0 / 3.0)


def test_register_rejects_duplicates_and_bad_names():
    with pytest.raises(ValueError):
        policies.register_policy(policies.resolve("lea"))
    with pytest.raises(ValueError):
        Policy(name="not an identifier", trajectory=lambda ctx: ctx.states)


def test_custom_policy_usable_as_engine_strategy():
    name = "always_stationary_test"
    if not policies.is_registered(name):
        @policies.register(name, description="predicts pi_g every round")
        def _traj(ctx):
            return jnp.broadcast_to(ctx.pi_g, ctx.states.shape).astype(jnp.float32)

    succ = throughput.simulate_strategies(
        jax.random.PRNGKey(0), LP, jnp.full((15,), 0.8), jnp.full((15,), 0.7),
        10.0, 3.0, 1.0, 40, strategies=(name, "lea"),
    )
    assert succ.shape == (40, 2)


def test_unknown_strategy_raises_with_policy_names():
    with pytest.raises(ValueError, match="not a registered policy"):
        throughput.simulate_strategies(
            jax.random.PRNGKey(0), LP, jnp.full((15,), 0.8),
            jnp.full((15,), 0.7), 10.0, 3.0, 1.0, 8, strategies=("nope",),
        )


# ---------------------------------------------------------------------------
# bit-identity: registry-resolved lea/oracle == pre-refactor closed forms
# ---------------------------------------------------------------------------

def test_registry_lea_matches_sequential_estimator_bitwise():
    """The ``"lea"`` policy IS the engine's estimator replay: equal, bit for
    bit, to sequential ``lea.update_estimator`` steps (the PR-1 invariant,
    now asserted through the registry path)."""
    states = _states(5, rounds=50, n=4)
    p_traj = policies.resolve("lea").p_good_trajectory(_ctx(states))
    est = lea.init_estimator(4)
    for m in range(50):
        want = jnp.where(
            est.seen_prev, lea.predicted_good_prob(est), jnp.full((4,), 0.5)
        )
        np.testing.assert_array_equal(np.asarray(p_traj[m]), np.asarray(want))
        est = lea.update_estimator(est, states[m])


def test_engine_policy_path_matches_manual_replay_bitwise():
    """The full refactored pipeline on ("lea", "oracle") reproduces a manual
    composition of the PR-1 building blocks — same key split, trajectory,
    closed-form p_good, one batched allocate, scoring — bit for bit."""
    key = jax.random.PRNGKey(11)
    p_gg, p_bb = jnp.full((15,), 0.85), jnp.full((15,), 0.65)
    rounds = 120
    succ = throughput.simulate_strategies(
        key, LP, p_gg, p_bb, 10.0, 3.0, 1.0, rounds,
        strategies=("lea", "oracle"),
    )
    # manual replay out of the building blocks
    k_traj, _ = jax.random.split(key)
    states = markov.sample_trajectory(k_traj, p_gg, p_bb, rounds)
    pi_g = markov.stationary_good_prob(p_gg, p_bb)
    p_lea = estimators.lea_p_good(states)
    p_ora = estimators.oracle_p_good(states, p_gg, p_bb, pi_g)
    loads, _ = lea.allocate(jnp.stack([p_lea, p_ora]), LP)
    speeds = jnp.where(states == 1, 10.0, 3.0)
    on_time = loads.astype(jnp.float32) / speeds <= 1.0 + 1e-9
    received = jnp.sum(jnp.where(on_time, loads, 0), axis=-1)
    want = jnp.moveaxis(received >= LP.kstar, 0, 1)
    np.testing.assert_array_equal(np.asarray(succ), np.asarray(want))


def test_policy_key_stream_does_not_perturb_deterministic_policies():
    """Adding a randomised policy to the tuple must not change the lea/oracle
    columns (policy-private keys are a disjoint fold_in stream)."""
    key = jax.random.PRNGKey(3)
    args = (jnp.full((15,), 0.8), jnp.full((15,), 0.7), 10.0, 3.0, 1.0, 80)
    base = throughput.simulate_strategies(
        key, LP, *args, strategies=("lea", "oracle"))
    mixed = throughput.simulate_strategies(
        key, LP, *args, strategies=("lea", "thompson", "oracle"))
    np.testing.assert_array_equal(np.asarray(base[:, 0]), np.asarray(mixed[:, 0]))
    np.testing.assert_array_equal(np.asarray(base[:, 1]), np.asarray(mixed[:, 2]))


# ---------------------------------------------------------------------------
# estimator closed forms
# ---------------------------------------------------------------------------

def test_windowed_counts_match_bruteforce_and_full_window_is_vanilla():
    states = _states(1, rounds=40, n=3)
    inc = np.asarray(estimators.transition_increments(states))
    for window in (1, 5, 17):
        got = np.asarray(estimators.windowed_counts_before_round(states, window))
        for m in range(40):
            lo, hi = max(m - 1 - window, 0), max(m - 1, 0)
            np.testing.assert_array_equal(got[m], inc[lo:hi].sum(axis=0)
                                          if hi > lo else np.zeros((3, 4)))
    # window >= M reproduces the vanilla counts bit-for-bit
    np.testing.assert_array_equal(
        np.asarray(estimators.windowed_counts_before_round(states, 40)),
        np.asarray(estimators.counts_before_round(states)),
    )


def test_windowed_policy_with_full_window_equals_lea_bitwise():
    states = _states(2, rounds=64, n=5)
    np.testing.assert_array_equal(
        np.asarray(policies.resolve("lea_window64").p_good_trajectory(_ctx(states))),
        np.asarray(policies.resolve("lea").p_good_trajectory(_ctx(states))),
    )


def test_discounted_counts_match_sequential_recurrence():
    states = _states(3, rounds=50, n=4)
    gamma = 0.9
    got = np.asarray(estimators.discounted_counts_before_round(states, gamma))
    inc = np.asarray(estimators.transition_increments(states), np.float64)
    z = np.zeros((4, 4))
    want = [np.zeros((4, 4)), np.zeros((4, 4))]
    for j in range(inc.shape[0] - 1):
        z = gamma * z + inc[j]
        want.append(z.copy())
    np.testing.assert_allclose(got, np.stack(want), rtol=1e-5, atol=1e-5)


def test_thompson_is_deterministic_per_key_and_bounded():
    states = _states(4, rounds=30, n=5)
    pol = policies.resolve("thompson")
    a = pol.p_good_trajectory(_ctx(states, key=jax.random.PRNGKey(1)))
    b = pol.p_good_trajectory(_ctx(states, key=jax.random.PRNGKey(1)))
    c = pol.p_good_trajectory(_ctx(states, key=jax.random.PRNGKey(2)))
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert not np.array_equal(np.asarray(a), np.asarray(c))
    assert np.all(np.asarray(a) >= 0.0) and np.all(np.asarray(a) <= 1.0)
    assert pol.needs_key


def test_ucb_is_optimistic_and_clipped():
    states = _states(6, rounds=40, n=5)
    p_ucb = np.asarray(policies.resolve("ucb").p_good_trajectory(_ctx(states)))
    p_lea = np.asarray(policies.resolve("lea").p_good_trajectory(_ctx(states)))
    # optimism: never below the point estimate (0.5 fill aside), never > 1
    assert np.all(p_ucb[1:] >= p_lea[1:] - 1e-6)
    assert np.all(p_ucb <= 1.0)


def test_oracle_tracks_time_varying_chain():
    rounds, n = 20, 4
    states = _states(7, rounds=rounds, n=n)
    p_gg = jnp.asarray(np.linspace(0.55, 0.95, rounds)[:, None]
                       * np.ones((1, n)), jnp.float32)
    p_bb = jnp.asarray(np.linspace(0.9, 0.5, rounds)[:, None]
                       * np.ones((1, n)), jnp.float32)
    got = np.asarray(estimators.oracle_p_good(
        states, p_gg, p_bb, markov.stationary_good_prob(p_gg[0], p_bb[0])))
    prev = np.asarray(states)
    for t in range(1, rounds):
        want = np.where(prev[t - 1] == 1, np.asarray(p_gg)[t],
                        1.0 - np.asarray(p_bb)[t])
        np.testing.assert_allclose(got[t], want, rtol=1e-6)


def test_policy_shape_validation():
    bad = Policy(name="bad_shape", trajectory=lambda ctx: ctx.states[:1])
    with pytest.raises(ValueError, match="returned shape"):
        bad.p_good_trajectory(_ctx(_states(0, rounds=6, n=3)))


# ---------------------------------------------------------------------------
# non-stationary engine paths
# ---------------------------------------------------------------------------

def test_constant_schedule_bit_identical_to_stationary():
    key = jax.random.PRNGKey(9)
    rounds = 90
    flat_g, flat_b = jnp.full((15,), 0.8), jnp.full((15,), 0.7)
    sched_g = jnp.broadcast_to(flat_g, (rounds, 15))
    sched_b = jnp.broadcast_to(flat_b, (rounds, 15))
    a = throughput.simulate_strategies(
        key, LP, flat_g, flat_b, 10.0, 3.0, 1.0, rounds,
        strategies=("lea", "static", "oracle"))
    b = throughput.simulate_strategies(
        key, LP, sched_g, sched_b, 10.0, 3.0, 1.0, rounds,
        strategies=("lea", "static", "oracle"))
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_time_varying_samplers_bit_equal_and_shift_regime():
    key = jax.random.PRNGKey(4)
    rounds, n = 4000, 8
    half = rounds // 2
    p_gg = jnp.concatenate([jnp.full((half, n), 0.95), jnp.full((half, n), 0.3)])
    p_bb = jnp.concatenate([jnp.full((half, n), 0.4), jnp.full((half, n), 0.9)])
    t1 = markov.sample_trajectory(key, p_gg, p_bb, rounds)
    t2 = markov.sample_trajectory_scan(key, p_gg, p_bb, rounds)
    np.testing.assert_array_equal(np.asarray(t1), np.asarray(t2))
    freq = np.asarray(t1, np.float64).mean(axis=1)
    # the two halves live in visibly different availability regimes
    assert freq[:half].mean() > 0.75 and freq[half:].mean() < 0.35


def test_time_varying_chain_shape_mismatch_raises():
    with pytest.raises(ValueError, match="one row per round"):
        throughput.simulate_strategies(
            jax.random.PRNGKey(0), LP, jnp.full((10, 15), 0.8),
            jnp.full((10, 15), 0.7), 10.0, 3.0, 1.0, 8, strategies=("lea",),
        )


def test_round_chunked_policies_bit_identical_unchunked():
    key = jax.random.PRNGKey(12)
    rounds = 96
    p_gg = jnp.broadcast_to(
        jnp.asarray(np.linspace(0.6, 0.95, rounds), jnp.float32)[:, None],
        (rounds, 15))
    p_bb = jnp.full((rounds, 15), 0.7)
    strategies = ("lea", "lea_window64", "lea_discount97", "thompson",
                  "static", "oracle")
    plain = throughput.simulate_strategies(
        key, LP, p_gg, p_bb, 10.0, 3.0, 1.0, rounds, strategies=strategies)
    for chunk in (1, 25, rounds):
        chunked = throughput.simulate_strategies(
            key, LP, p_gg, p_bb, 10.0, 3.0, 1.0, rounds,
            strategies=strategies, round_chunk=chunk)
        np.testing.assert_array_equal(np.asarray(plain), np.asarray(chunked))
