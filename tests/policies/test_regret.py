"""Regret accounting + the ISSUE's acceptance criteria: sublinear LEA regret
on stationary chains (>= 8 seeds) and windowed/discounted policies strictly
beating vanilla LEA on the non-stationary families."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import policies, sweeps
from repro.core import throughput
from repro.core.lea import LoadParams
from repro.policies import regret

LP = LoadParams(n=15, kstar=99, ell_g=10, ell_b=3)


def _sweep(strategies, rounds=200, seeds=3, p_gg=0.8, p_bb=0.7):
    keys = jnp.stack([jax.random.PRNGKey(100 + s) for s in range(seeds)])
    pg = jnp.broadcast_to(jnp.full((15,), p_gg), (seeds, 15))
    pb = jnp.broadcast_to(jnp.full((15,), p_bb), (seeds, 15))
    return throughput.sweep(keys, LP, pg, pb, 10.0, 3.0, 1.0, rounds,
                            strategies=strategies)


# ---------------------------------------------------------------------------
# regret mechanics
# ---------------------------------------------------------------------------

def test_per_round_and_cumulative_shapes_and_self_regret():
    strategies = ("lea", "static", "oracle")
    succ = _sweep(strategies, rounds=64, seeds=2)
    per = regret.per_round_regret(succ, strategies, "lea")
    cum = regret.cumulative_regret(succ, strategies, "lea")
    assert per.shape == (2, 64) and cum.shape == (2, 64)
    np.testing.assert_allclose(np.asarray(cum[:, -1]),
                               np.asarray(per).sum(axis=-1), atol=1e-5)
    # the reference has identically-zero regret against itself
    self_reg = regret.cumulative_regret(succ, strategies, "oracle")
    np.testing.assert_array_equal(np.asarray(self_reg), np.zeros((2, 64)))


def test_final_regret_matches_manual_sum_and_unbatched_input():
    strategies = ("lea", "oracle")
    succ = _sweep(strategies, rounds=80, seeds=2)
    finals = regret.final_regret(succ, strategies)
    manual = (np.asarray(succ[..., 1], np.float64)
              - np.asarray(succ[..., 0], np.float64)).sum(axis=-1)
    np.testing.assert_allclose(finals["lea"], manual, atol=1e-5)
    np.testing.assert_array_equal(finals["oracle"], np.zeros(2))
    # unbatched (M, S) input: scalar-shaped outputs
    one = regret.final_regret(np.asarray(succ)[0], strategies)
    assert one["lea"].shape == ()
    np.testing.assert_allclose(one["lea"], manual[0], atol=1e-5)


def test_missing_reference_raises():
    succ = _sweep(("lea", "static"), rounds=16, seeds=1)
    with pytest.raises(ValueError, match="oracle"):
        regret.per_round_regret(succ, ("lea", "static"), "lea")
    with pytest.raises(ValueError, match="not in"):
        regret.per_round_regret(succ, ("lea", "static"), "nope", "lea")


def test_regret_curve_summary_horizons():
    strategies = ("lea", "oracle")
    succ = _sweep(strategies, rounds=100, seeds=2)
    rounds_at, mean_cum = regret.regret_curve_summary(
        succ, strategies, "lea", points=5)
    assert rounds_at[-1] == 100 and len(rounds_at) == len(mean_cum) == 5
    cum = np.asarray(regret.cumulative_regret(succ, strategies, "lea"),
                     np.float64).mean(axis=0)
    np.testing.assert_allclose(mean_cum[-1], cum[-1], atol=1e-6)


# ---------------------------------------------------------------------------
# acceptance: sublinear regret on stationary chains (averaged over 8 seeds)
# ---------------------------------------------------------------------------

def test_lea_regret_sublinear_on_stationary_chain():
    """Thm 5.1 empirically, as regret: LEA's mean cumulative regret vs the
    genie grows sublinearly — the per-round regret RATE at the full horizon
    is well below the early-horizon rate, and the total stays far under any
    linear envelope."""
    strategies = ("lea", "oracle")
    rounds, seeds = 3000, 8
    succ = _sweep(strategies, rounds=rounds, seeds=seeds)
    cum = np.asarray(regret.cumulative_regret(succ, strategies, "lea"),
                     np.float64).mean(axis=0)
    early, late = 250, rounds
    rate_early = cum[early - 1] / early
    rate_late = cum[late - 1] / late
    assert rate_late < 0.75 * rate_early, (rate_early, rate_late)
    assert 0.0 <= cum[late - 1] < 0.01 * rounds, cum[late - 1]


# ---------------------------------------------------------------------------
# acceptance: adaptive policies beat vanilla LEA on non-stationary families
# ---------------------------------------------------------------------------

def test_windowed_and_discounted_beat_vanilla_lea_on_drifting_chains():
    res = sweeps.run("drifting_chains", periods=(400,), rounds=1600, seeds=4)
    (r,) = res
    assert r.throughput["lea_window64"] > r.throughput["lea"], r.throughput
    assert r.throughput["lea_discount97"] > r.throughput["lea"], r.throughput
    # regret orders the same way, and the genie stays on top
    assert r.regret["lea_window64"] < r.regret["lea"]
    assert r.throughput["oracle"] >= r.throughput["lea_window64"] - 1e-9


def test_adaptive_policies_beat_vanilla_lea_on_regime_switch():
    res = sweeps.run("regime_switch", dwells=(250,), rounds=1600, seeds=4)
    (r,) = res
    best_adaptive = max(r.throughput["lea_window64"],
                       r.throughput["lea_discount97"])
    assert best_adaptive > r.throughput["lea"], r.throughput


# ---------------------------------------------------------------------------
# sweeps integration: regret columns, scheduled grouping
# ---------------------------------------------------------------------------

def test_manifest_rows_carry_regret_columns():
    res = sweeps.run("drifting_chains", periods=(300,), rounds=300, seeds=2)
    doc = sweeps.manifest(res, bench="unit_policies")
    row = doc["results"][0]
    for s in ("lea", "lea_window64", "lea_discount97", "static"):
        assert f"regret_{s}" in row
    assert "regret_oracle" not in row          # the reference itself
    assert "drifting_chains" in doc["families"]


def test_no_oracle_no_regret_columns():
    res = sweeps.run("fig4", rounds=32)        # lea vs static_single only
    assert all(r.regret == {} for r in res)
    assert all("regret_lea" not in r.row() for r in res)


def test_scheduled_scenarios_group_apart_from_stationary():
    drift = sweeps.expand("drifting_chains", periods=(200,), rounds=400)
    # a stationary clone with the same (lp, rounds, strategies) signature
    import dataclasses
    flat = dataclasses.replace(drift[0], name="flat_clone", schedule=())
    groups = sweeps.build_groups(drift + (flat,))
    assert len(groups) == 2
    shapes = sorted(g.batch.p_gg.shape for g in groups)
    assert shapes == [(1, 15), (1, 400, 15)]


def test_schedule_validation():
    import dataclasses
    sc = sweeps.expand("drifting_chains", periods=(200,), rounds=400)[0]
    with pytest.raises(ValueError, match="start at round 0"):
        dataclasses.replace(sc, schedule=((10,) + sc.schedule[0][1:],))
    bad_rows = (sc.schedule[0], (500, sc.schedule[1][1], sc.schedule[1][2]))
    with pytest.raises(ValueError, match="beyond rounds"):
        dataclasses.replace(sc, schedule=bad_rows)
    with pytest.raises(ValueError, match="round-0 rows"):
        dataclasses.replace(sc, p_gg=(0.5,) * 15)
    with pytest.raises(ValueError, match="must increase"):
        dataclasses.replace(
            sc, schedule=(sc.schedule[0], (0,) + sc.schedule[1][1:]))


def test_registry_policy_names_valid_in_scenarios_and_sweep_executor():
    """A dynamic policy spelling flows end to end: scenario validation, the
    executor's compile, the results layer."""
    drift = sweeps.expand(
        "drifting_chains", periods=(150,), rounds=150,
        strategies=("lea", "lea_window32", "oracle"),
    )
    res = sweeps.run(drift)
    (r,) = res
    assert set(r.throughput) == {"lea", "lea_window32", "oracle"}
    assert "lea_window32" in r.regret
