"""Validation of the trip-count-aware HLO cost walker (the roofline source).

Runs in a subprocess with 4 fake devices so the sharded case exercises real
SPMD collectives without leaking XLA_FLAGS into the main test process.
"""

import os
import subprocess
import sys
import textwrap

_BODY = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import sys
    sys.path.insert(0, %r)
    import jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch import hlo_cost

    # 1) scan trip-count multiplication (fwd only): 8 trips x 2*256^3
    def f(x, w):
        def body(c, _):
            return jax.nn.gelu(c @ w), None
        out, _ = jax.lax.scan(body, x, None, length=8)
        return out
    x = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    w = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    c = jax.jit(f).lower(x, w).compile()
    costs = hlo_cost.analyze(c.as_text())
    want = 8 * 2 * 256**3
    assert abs(costs.matmul_flops - want) / want < 1e-6, costs.matmul_flops

    # 2) sharded: per-device flops = total/4; all-reduce counted x trips
    mesh = jax.make_mesh((4,), ("model",))
    def g(x, w):
        def body(c, _):
            h = c @ w
            return jax.nn.gelu(h @ w.T), None
        out, _ = jax.lax.scan(body, x, None, length=8)
        return out
    ws = NamedSharding(mesh, P(None, "model"))
    xs = NamedSharding(mesh, P())
    with mesh:
        cc = jax.jit(g, in_shardings=(xs, ws), out_shardings=xs).lower(x, w).compile()
    c2 = hlo_cost.analyze(cc.as_text())
    want2 = 16 * 2 * 256**3 / 4
    assert abs(c2.matmul_flops - want2) / want2 < 1e-6, c2.matmul_flops
    assert c2.per_collective.get("all-reduce", 0) == 8 * 256 * 256 * 4, c2.per_collective

    # 3) in-place cache update: DUS traffic ~ slice, not buffer
    def h(cache, tok):
        def body(c, ck):
            new = jax.lax.dynamic_update_slice(ck, tok.astype(ck.dtype), (0, 5, 0))
            return c + 1, new
        n, out = jax.lax.scan(body, jnp.int32(0), cache)
        return out
    cache = jax.ShapeDtypeStruct((4, 8, 1024, 128), jnp.bfloat16)
    tok = jax.ShapeDtypeStruct((8, 1, 128), jnp.float32)
    c3 = hlo_cost.analyze(jax.jit(h, donate_argnums=(0,)).lower(cache, tok).compile().as_text())
    # naive operand+output accounting would charge the full 16.8 MB stack in
    # and out on every trip (~134 MB); slice-aware stays far under even with
    # the CPU backend's one-time f32 convert copies.
    naive = 4 * (2 * 4 * 8 * 1024 * 128 * 4)
    assert c3.hbm_bytes < naive, (c3.hbm_bytes, naive)  # ys-rebuild slices, not buffers
    print("HLO_COST_OK")
""")

_SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")


def test_hlo_cost_walker():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", _BODY % _SRC],
                          capture_output=True, text=True, timeout=600, env=env)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "HLO_COST_OK" in proc.stdout


def _run_cli(*args: str):
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = os.pathsep.join(
        [_SRC] + env.get("PYTHONPATH", "").split(os.pathsep)
    ).rstrip(os.pathsep)
    return subprocess.run(
        [sys.executable, "-m", "repro.launch.hlo_cost", *args],
        capture_output=True, text=True, timeout=600, env=env,
    )


def test_cli_lists_the_engine_pool_path_entry_points():
    proc = _run_cli("--list")
    assert proc.returncode == 0, proc.stderr
    names = proc.stdout.split()
    assert names == ["simulate_strategies_pool", "sweep_faults",
                     "sweep_serving"]


def test_cli_rejects_unknown_entry_points_with_listing():
    proc = _run_cli("no_such_entry")
    assert proc.returncode != 0
    assert "no_such_entry" in proc.stderr
    assert "simulate_strategies_pool" in proc.stderr


def test_estimate_entry_lowers_the_pool_engine_and_costs_it():
    from repro.launch import hlo_cost

    row = hlo_cost.estimate_entry("simulate_strategies_pool")
    assert row["target"] == "simulate_strategies_pool"
    assert row["flops"] > 0 and row["hbm_bytes"] > 0
    assert row["flops_per_round"] == row["flops"] / row["rounds"]
    assert row["arithmetic_intensity"] > 0
    import json

    json.dumps(row, allow_nan=False)     # obs_report embeds it verbatim


def test_estimate_entry_rejects_unknown_names():
    import pytest

    from repro.launch import hlo_cost

    with pytest.raises(KeyError):
        hlo_cost.estimate_entry("nope")
