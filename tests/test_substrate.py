"""Substrate tests: data pipeline, checkpointing, compression, coded-DP FT."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.data import DataPipeline
from repro.checkpoint import CheckpointManager, latest_step, restore, save
from repro.runtime.compression import make_compressor
from repro.runtime.fault_tolerance import CodedDPConfig, CodedDataParallelExecutor


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_pipeline_deterministic_and_restorable():
    p1 = DataPipeline(1000, 8, 16, seed=3)
    b1 = [p1.next() for _ in range(3)]
    p2 = DataPipeline(1000, 8, 16, seed=3)
    p2.restore({"step": 2, "seed": 3})
    b2 = p2.next()
    np.testing.assert_array_equal(b1[2]["tokens"], b2["tokens"])


def test_pipeline_host_sharding_partitions_global_batch():
    full = DataPipeline(1000, 8, 16, seed=1)
    ga = full.next()["tokens"]
    parts = []
    for h in range(4):
        p = DataPipeline(1000, 8, 16, seed=1, host_id=h, host_count=4)
        parts.append(p.next()["tokens"])
    np.testing.assert_array_equal(ga, np.concatenate(parts, axis=0))


def test_pipeline_tokens_in_vocab():
    p = DataPipeline(50, 4, 32, seed=0)
    t = p.next()["tokens"]
    assert t.min() >= 0 and t.max() < 50


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

def _tree():
    return {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.bfloat16)}}


def test_checkpoint_roundtrip(tmp_path):
    d = str(tmp_path)
    tree = _tree()
    save(d, 7, tree, extra_meta={"cursor": {"step": 7, "seed": 0}})
    assert latest_step(d) == 7
    out, meta = restore(d, 7, tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))
    assert meta["cursor"]["step"] == 7


def test_checkpoint_atomicity_ignores_tmp(tmp_path):
    d = str(tmp_path)
    tree = _tree()
    save(d, 1, tree)
    # simulate crash mid-write: tmp dir exists without rename
    os.makedirs(os.path.join(d, "step_2.tmp"))
    assert latest_step(d) == 1


def test_checkpoint_manager_async_and_gc(tmp_path):
    d = str(tmp_path)
    mgr = CheckpointManager(d, keep=2)
    tree = _tree()
    for s in (1, 2, 3, 4):
        mgr.save_async(s, tree)
    mgr.wait()
    mgr._gc()
    assert latest_step(d) == 4
    steps = sorted(int(n.split("_")[1]) for n in os.listdir(d) if n.startswith("step_"))
    assert len(steps) <= 2
    s, out, _ = mgr.restore_latest(tree)
    assert s == 4
    np.testing.assert_array_equal(np.asarray(out["a"]), np.asarray(tree["a"]))


def test_checkpoint_structure_mismatch_raises(tmp_path):
    d = str(tmp_path)
    save(d, 1, _tree())
    with pytest.raises(ValueError, match="structure mismatch"):
        restore(d, 1, {"a": jnp.zeros((2, 3)), "zz": jnp.zeros((4,))})


# ---------------------------------------------------------------------------
# compression
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", ["int8", "topk"])
def test_compression_error_feedback_contract(kind):
    """EF invariant: compressed + residual == accumulated true gradient."""
    init, apply = make_compressor(kind, k_frac=0.25)
    g = {"w": jnp.asarray(np.random.default_rng(0).normal(size=(64, 8)), jnp.float32)}
    state = init(g)
    out, new_state = apply(g, state)
    recon = jax.tree.map(lambda a, b: a + b, out, new_state)
    np.testing.assert_allclose(np.asarray(recon["w"]), np.asarray(g["w"]), rtol=2e-2, atol=2e-2)


def test_int8_compression_bounded_error():
    init, apply = make_compressor("int8")
    g = {"w": jnp.linspace(-1, 1, 1000, dtype=jnp.float32)}
    out, _ = apply(g, init(g))
    err = np.abs(np.asarray(out["w"]) - np.asarray(g["w"])).max()
    assert err <= (1.0 / 127.0) + 1e-5


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000), k_frac=st.floats(0.05, 0.9))
def test_topk_keeps_largest(seed, k_frac):
    init, apply = make_compressor("topk", k_frac=k_frac)
    rng = np.random.default_rng(seed)
    g = {"w": jnp.asarray(rng.normal(size=(128,)), jnp.float32)}
    out, _ = apply(g, init(g))
    kept = np.asarray(out["w"]) != 0
    dropped_max = np.abs(np.asarray(g["w"]))[~kept].max() if (~kept).any() else 0.0
    kept_min = np.abs(np.asarray(g["w"]))[kept].min()
    assert kept_min >= dropped_max - 1e-6


def test_ef_accumulates_dropped_signal():
    """A direction always dropped by top-k must eventually pass via EF."""
    init, apply = make_compressor("topk", k_frac=0.5)
    g = {"w": jnp.asarray([1.0, 0.1], jnp.float32)}   # second always loses
    state = init(g)
    passed_small = False
    for _ in range(10):
        out, state = apply(g, state)
        if np.asarray(out["w"])[1] != 0:
            passed_small = True
            break
    assert passed_small


# ---------------------------------------------------------------------------
# coded-DP fault tolerance (the paper inside the trainer)
# ---------------------------------------------------------------------------

def _quadratic_grad(params, batch):
    # toy model: params w; loss = mean((x @ w - y)^2)
    def loss(w):
        pred = batch["x"] @ w
        return jnp.mean((pred - batch["y"]) ** 2)
    return {"w": jax.grad(lambda w: loss(w["w"]))(params)["w"]}


def _toy_batch(k=16, rows=2):
    rng = np.random.default_rng(0)
    return {
        "x": jnp.asarray(rng.normal(size=(k * rows, 4)), jnp.float32),
        "y": jnp.asarray(rng.normal(size=(k * rows,)), jnp.float32),
    }


def test_coded_dp_round_gradient_matches_uncoded_mean():
    cfg = CodedDPConfig(n_workers=8, r=4, k=16, deadline=1.0, mu_g=10, mu_b=3,
                        p_gg=0.95, p_bb=0.05)  # mostly good: rounds succeed
    ex = CodedDataParallelExecutor(cfg, _quadratic_grad, seed=1)
    params = {"w": jnp.zeros((4,), jnp.float32)}
    batch = _toy_batch()
    got = None
    for _ in range(20):
        g, info = ex.round(params, batch)
        if g is not None:
            got = g
            break
    assert got is not None
    want = _quadratic_grad(params, batch)
    np.testing.assert_allclose(np.asarray(got["w"]), np.asarray(want["w"]),
                               rtol=1e-5, atol=1e-6)


def test_coded_dp_learns_and_succeeds_often():
    cfg = CodedDPConfig(n_workers=8, r=4, k=16, deadline=1.0, mu_g=10, mu_b=3,
                        p_gg=0.9, p_bb=0.4)
    ex = CodedDataParallelExecutor(cfg, _quadratic_grad, seed=0)
    params = {"w": jnp.zeros((4,), jnp.float32)}
    batch = _toy_batch()
    for _ in range(60):
        ex.round(params, batch)
    assert ex.timely_throughput > 0.5, ex.timely_throughput


def test_coded_dp_dead_worker_feasibility():
    cfg = CodedDPConfig(n_workers=5, r=4, k=16)
    ex = CodedDataParallelExecutor(cfg, _quadratic_grad)
    assert ex.decode_feasible          # 5*4 = 20 >= 16
    ex.mark_dead(0)
    assert ex.decode_feasible          # 4*4 = 16 >= 16: exactly feasible
    ex.mark_dead(1)
    assert not ex.decode_feasible      # 12 < 16: restart-from-checkpoint


def test_coded_dp_estimator_state_roundtrip():
    cfg = CodedDPConfig(n_workers=6, r=4, k=12)
    ex = CodedDataParallelExecutor(cfg, _quadratic_grad, seed=2)
    params = {"w": jnp.zeros((4,), jnp.float32)}
    batch = {"x": jnp.zeros((12 * 2, 4)), "y": jnp.zeros((12 * 2,))}
    for _ in range(5):
        ex.round(params, batch)
    sd = ex.state_dict()
    ex2 = CodedDataParallelExecutor(cfg, _quadratic_grad, seed=99)
    ex2.load_state_dict(sd)
    np.testing.assert_array_equal(np.asarray(ex.est.counts), np.asarray(ex2.est.counts))
    assert ex2.rounds == ex.rounds
