"""Sweep of the fused coded-gradient kernel vs oracle + vs core.chunk_gradient."""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.kernels.coded_gradient.kernel import coded_gradient_pallas
from repro.kernels.coded_gradient.ref import coded_gradient_ref
from repro.kernels.coded_gradient import ops


@pytest.mark.parametrize("nr,rows,cols,p", [(6, 8, 32, 1), (10, 25, 300, 1), (4, 30, 64, 4)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_gradient_matches_ref(nr, rows, cols, p, dtype):
    rng = np.random.default_rng(nr + rows)
    x = jnp.asarray(rng.normal(size=(nr, rows, cols)), dtype)
    y = jnp.asarray(rng.normal(size=(nr, rows, p)), dtype)
    w = jnp.asarray(rng.normal(size=(cols, p)), dtype)
    got = coded_gradient_pallas(x, y, w, interpret=True)
    want = coded_gradient_ref(x, y, w)
    tol = 6e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), rtol=tol, atol=tol
    )


def test_vector_target_wrapper_matches_core():
    from repro.core.coded_ops import chunk_gradient
    import jax

    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(5, 10, 20)), jnp.float32)
    y = jnp.asarray(rng.normal(size=(5, 10)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(20,)), jnp.float32)
    got = ops.coded_gradient(x, y, w, interpret=True)
    want = jax.vmap(chunk_gradient, in_axes=(0, 0, None))(x, y, w)
    assert got.shape == (5, 20)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


def test_vmem_budget_guard():
    x = jnp.zeros((1, 1024, 4096), jnp.float32)
    y = jnp.zeros((1, 1024, 1), jnp.float32)
    w = jnp.zeros((4096, 1), jnp.float32)
    with pytest.raises(ValueError, match="VMEM"):
        coded_gradient_pallas(x, y, w, interpret=True)
