"""The shared kernel dispatch helper: defaults, env overrides, validation."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import dispatch


def test_defaults_off_tpu(monkeypatch):
    monkeypatch.delenv(dispatch.ENV_IMPL, raising=False)
    monkeypatch.delenv(dispatch.ENV_INTERPRET, raising=False)
    # the CI container is CPU: host impl + interpret mode
    assert not dispatch.on_tpu()
    assert dispatch.default_interpret(None) is True
    assert dispatch.resolve_impl(None, allowed=("pallas", "ref")) == "ref"
    assert dispatch.resolve_impl(
        None, allowed=("pallas", "dot", "ref"), host_impl="dot"
    ) == "dot"


def test_explicit_arguments_win_over_env(monkeypatch):
    monkeypatch.setenv(dispatch.ENV_IMPL, "pallas")
    monkeypatch.setenv(dispatch.ENV_INTERPRET, "0")
    assert dispatch.resolve_impl("ref", allowed=("pallas", "ref")) == "ref"
    assert dispatch.default_interpret(True) is True


def test_env_impl_override(monkeypatch):
    monkeypatch.setenv(dispatch.ENV_IMPL, "ref")
    assert dispatch.resolve_impl(None, allowed=("pallas", "ref")) == "ref"
    assert dispatch.resolve_impl(
        None, allowed=("pallas", "dot", "ref"), host_impl="dot"
    ) == "ref"
    # a forced name outside the dispatcher's set raises, never falls back
    monkeypatch.setenv(dispatch.ENV_IMPL, "dot")
    with pytest.raises(ValueError, match="unknown impl"):
        dispatch.resolve_impl(None, allowed=("pallas", "ref"))


def test_env_interpret_override(monkeypatch):
    monkeypatch.setenv(dispatch.ENV_INTERPRET, "0")
    assert dispatch.default_interpret(None) is False
    monkeypatch.setenv(dispatch.ENV_INTERPRET, "true")
    assert dispatch.default_interpret(None) is True
    monkeypatch.setenv(dispatch.ENV_INTERPRET, "maybe")
    with pytest.raises(ValueError, match="boolean"):
        dispatch.default_interpret(None)


def test_unknown_explicit_impl_raises():
    with pytest.raises(ValueError, match="unknown impl"):
        dispatch.resolve_impl("nope", allowed=("pallas", "ref"))


def test_env_override_reaches_migrated_dispatchers(monkeypatch):
    """REPRO_KERNEL_IMPL flows through the migrated ops call sites."""
    from repro.kernels.gf import matmul_gf
    from repro.kernels.poisson_binomial import success_tails

    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.integers(0, 1000, (4, 6)), jnp.int32)
    b = jnp.asarray(rng.integers(0, 1000, (6, 3)), jnp.int32)
    base = np.asarray(matmul_gf(a, b, impl="ref"))
    monkeypatch.setenv(dispatch.ENV_IMPL, "ref")
    np.testing.assert_array_equal(np.asarray(matmul_gf(a, b)), base)

    p = jnp.asarray(np.sort(rng.uniform(0, 1, (3, 5)), axis=-1)[:, ::-1].copy(),
                    jnp.float32)
    w = np.asarray([1, 1, 2, 3, 4], np.int32)
    want = np.asarray(success_tails(p, w, impl="ref"))
    np.testing.assert_array_equal(np.asarray(success_tails(p, w)), want)

    # forcing an impl a dispatcher does not support raises loudly
    monkeypatch.setenv(dispatch.ENV_IMPL, "dot")
    with pytest.raises(ValueError, match="unknown impl"):
        success_tails(p, w)
