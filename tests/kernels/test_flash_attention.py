"""Shape/dtype/mask sweep of the flash-attention kernel vs the jnp oracle."""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.kernels.flash_attention.kernel import flash_attention_pallas
from repro.kernels.flash_attention.ref import attention_ref


def _rand_qkv(rng, b, hq, hkv, sq, sk, d, dtype):
    q = jnp.asarray(rng.normal(size=(b, hq, sq, d)), dtype)
    k = jnp.asarray(rng.normal(size=(b, hkv, sk, d)), dtype)
    v = jnp.asarray(rng.normal(size=(b, hkv, sk, d)), dtype)
    return q, k, v


@pytest.mark.parametrize("hq,hkv", [(4, 4), (4, 2), (8, 1)])
@pytest.mark.parametrize("s", [128, 192, 256])
@pytest.mark.parametrize("d", [32, 64])
def test_causal_gqa_matches_ref(hq, hkv, s, d):
    rng = np.random.default_rng(hq * s + d)
    q, k, v = _rand_qkv(rng, 2, hq, hkv, s, s, d, jnp.float32)
    got = flash_attention_pallas(q, k, v, causal=True, block_q=64, block_k=64, interpret=True)
    want = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-4), (jnp.bfloat16, 3e-2)])
def test_dtypes(dtype, tol):
    rng = np.random.default_rng(0)
    q, k, v = _rand_qkv(rng, 1, 4, 2, 128, 128, 64, dtype)
    got = flash_attention_pallas(q, k, v, causal=True, block_q=64, block_k=64, interpret=True)
    want = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), rtol=tol, atol=tol
    )


def test_noncausal_full_attention():
    rng = np.random.default_rng(1)
    q, k, v = _rand_qkv(rng, 1, 2, 2, 96, 96, 32, jnp.float32)
    got = flash_attention_pallas(q, k, v, causal=False, block_q=32, block_k=32, interpret=True)
    want = attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


def test_sliding_window():
    rng = np.random.default_rng(2)
    q, k, v = _rand_qkv(rng, 1, 2, 2, 256, 256, 32, jnp.float32)
    got = flash_attention_pallas(
        q, k, v, causal=True, window=64, block_q=64, block_k=64, interpret=True
    )
    want = attention_ref(q, k, v, causal=True, window=64)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


def test_cross_attention_decode_alignment():
    """Sq < Sk (decode/cross): query positions right-align to the KV end."""
    rng = np.random.default_rng(3)
    q, k, v = _rand_qkv(rng, 1, 2, 1, 8, 256, 32, jnp.float32)
    got = flash_attention_pallas(q, k, v, causal=True, block_q=8, block_k=64, interpret=True)
    want = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


def test_ragged_seqlen_padding():
    """Non-multiple-of-block lengths exercise the padding/masking path."""
    rng = np.random.default_rng(4)
    q, k, v = _rand_qkv(rng, 1, 2, 2, 100, 100, 32, jnp.float32)
    got = flash_attention_pallas(q, k, v, causal=True, block_q=64, block_k=64, interpret=True)
    want = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)
