"""repro.kernels.gf: exact Mersenne-31 arithmetic, kernel-vs-ref bit-equality.

Residues are exact, so every assertion here is array_equal — never allclose.
The numpy int64 path is the independent oracle for the primitives; the lax
reference is the oracle for the Pallas kernel (interpret mode on CPU) and
the limb-decomposed dot path.
"""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.kernels import gf
from repro.kernels.gf import ref as gf_ref
from repro.kernels.gf.kernel import matmul_gf_pallas

P = gf.FIELD_P

# always-on boundary residues: additive/multiplicative identities and the
# extremes where limb splits and folds are most likely to break
_BOUNDARY = np.array([0, 1, 2, P - 1, P - 2, 2**30, 2**16, 2**15, 0xFFFF],
                     dtype=np.int64)


def _rand_residues(rng, shape):
    vals = rng.integers(0, P, size=shape).astype(np.int64)
    flat = vals.reshape(-1)
    take = min(flat.shape[0], _BOUNDARY.shape[0])
    flat[:take] = _BOUNDARY[:take]          # splice boundary values in
    return flat.reshape(shape)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), n=st.integers(1, 257))
def test_mul_gf_matches_numpy_int64(seed, n):
    rng = np.random.default_rng(seed)
    a = _rand_residues(rng, (n,))
    b = _rand_residues(rng, (n,))[::-1].copy()
    got = np.asarray(
        gf.mul_gf(gf.to_gf(a.astype(np.int32)), gf.to_gf(b.astype(np.int32))),
        np.int64,
    )
    np.testing.assert_array_equal(got, (a * b) % P)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_add_sub_inv_gf_match_numpy_int64(seed):
    rng = np.random.default_rng(seed)
    a = _rand_residues(rng, (64,))
    b = _rand_residues(rng, (64,))[::-1].copy()
    ga, gb = gf.to_gf(a.astype(np.int32)), gf.to_gf(b.astype(np.int32))
    np.testing.assert_array_equal(np.asarray(gf.add_gf(ga, gb), np.int64), (a + b) % P)
    np.testing.assert_array_equal(np.asarray(gf.sub_gf(ga, gb), np.int64), (a - b) % P)
    nz = a[a != 0]
    inv = np.asarray(gf.inv_gf(gf.to_gf(nz.astype(np.int32))), np.int64)
    np.testing.assert_array_equal((nz * inv) % P, 1)
    # inv of 0 is defined as 0 (never used by callers, but must not explode)
    assert int(gf.inv_gf(gf.to_gf(np.int32(0)))) == 0


def test_rot_gf_is_power_of_two_multiplication():
    rng = np.random.default_rng(0)
    v = _rand_residues(rng, (128,))
    gv = gf.to_gf(v.astype(np.int32))
    for s in (0, 1, 7, 8, 16, 24, 30, 31, 40, 48, 62):
        got = np.asarray(gf_ref.rot_gf(gv, s), np.int64)
        np.testing.assert_array_equal(got, (v * pow(2, s, P)) % P)


def test_to_gf_reduces_signed_and_unsigned():
    x = np.array([-1, -P, P - 1, 5], dtype=np.int32)
    np.testing.assert_array_equal(
        np.asarray(gf.to_gf(x), np.int64), np.array([P - 1, 0, P - 1, 5]))
    u = np.array([P, P + 1, 2**32 - 1], dtype=np.uint32)
    np.testing.assert_array_equal(
        np.asarray(gf.to_gf(u), np.int64), np.array([0, 1, 1]))


def _np_matmul_gf(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    out = np.zeros((a.shape[0], b.shape[1]), np.int64)
    for k in range(a.shape[1]):
        out = (out + a[:, k : k + 1] * b[k : k + 1, :]) % P
    return out


@settings(max_examples=10, deadline=None)
@given(
    m=st.integers(1, 40),
    c=st.integers(1, 300),     # crosses the dot path's 256-wide K-chunk
    n=st.integers(1, 140),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_gf_all_impls_bit_equal_numpy(m, c, n, seed):
    rng = np.random.default_rng(seed)
    a = _rand_residues(rng, (m, c))
    b = _rand_residues(rng, (c, n))
    want = _np_matmul_gf(a, b)
    for impl in ("ref", "dot", "pallas"):
        got = np.asarray(
            gf.matmul_gf(a.astype(np.int32), b.astype(np.int32), impl=impl),
            np.int64,
        )
        np.testing.assert_array_equal(got, want, err_msg=f"impl={impl}")


def test_pallas_kernel_multi_tile_grid_accumulation():
    """Small explicit blocks force a (2+, 2+, 2+) grid: the K-innermost
    revisiting accumulation and edge-tile zero padding must stay exact."""
    rng = np.random.default_rng(3)
    a = _rand_residues(rng, (19, 37))
    b = _rand_residues(rng, (37, 150))
    want = _np_matmul_gf(a, b)
    got = matmul_gf_pallas(
        gf.to_gf(a.astype(np.int32)), gf.to_gf(b.astype(np.int32)),
        block_m=8, block_n=128, block_k=16, interpret=True,
    )
    np.testing.assert_array_equal(np.asarray(got, np.int64), want)


def test_matmul_gf_dot_pins_highest_precision():
    """GPU guard CPU CI can run: every dot_general in the limb GEMM path
    must trace with Precision.HIGHEST, else Ampere+ TF32 (10-bit mantissa)
    silently rounds the limb products and breaks bit-exactness."""
    import jax

    a = jnp.zeros((4, 300), jnp.uint32)       # crosses the 256-wide K-chunk
    b = jnp.zeros((300, 5), jnp.uint32)

    def walk(jaxpr):
        for eqn in jaxpr.eqns:
            if eqn.primitive.name == "dot_general":
                yield eqn
            for v in eqn.params.values():
                if hasattr(v, "jaxpr"):
                    yield from walk(v.jaxpr)

    from repro.kernels.gf.ops import matmul_gf_dot

    dots = list(walk(jax.make_jaxpr(matmul_gf_dot)(a, b).jaxpr))
    assert dots, "expected at least one dot_general in matmul_gf_dot"
    hi = jax.lax.Precision.HIGHEST
    for eqn in dots:
        prec = eqn.params["precision"]
        assert prec in (hi, (hi, hi)), f"dot_general precision {prec!r}"


def test_pallas_rejects_non_lane_blocks_outside_interpret():
    """The Mosaic lane-dim contract (bk, bn multiples of 128) is enforced,
    not just documented: small block_k/block_n only fly in interpret mode."""
    a = gf.to_gf(np.zeros((8, 256), np.int32))
    b = gf.to_gf(np.zeros((256, 256), np.int32))
    for kwargs in ({"block_k": 64}, {"block_n": 64}):
        try:
            matmul_gf_pallas(a, b, interpret=False, **kwargs)
        except ValueError as e:
            assert "128" in str(e)
        else:  # pragma: no cover
            raise AssertionError("expected ValueError")
        # the same blocks are honoured under interpret=True
        out = matmul_gf_pallas(a, b, interpret=True, **kwargs)
        assert out.shape == (8, 256)


def test_matmul_gf_rejects_bad_shapes_and_impl():
    a = np.zeros((2, 3), np.int32)
    b = np.zeros((4, 2), np.int32)
    for fn in (lambda: gf.matmul_gf(a, b), lambda: gf.matmul_gf(a[0], b)):
        try:
            fn()
        except ValueError:
            pass
        else:  # pragma: no cover
            raise AssertionError("expected ValueError")
    try:
        gf.matmul_gf(np.zeros((2, 4), np.int32), b, impl="nope")
    except ValueError as e:
        assert "nope" in str(e)
    else:  # pragma: no cover
        raise AssertionError("expected ValueError")


@settings(max_examples=10, deadline=None)
@given(
    e=st.integers(1, 12),
    j=st.integers(2, 10),
    b=st.integers(1, 4),
    seed=st.integers(0, 2**31 - 1),
)
def test_lagrange_basis_gf_matches_numpy_oracle(e, j, b, seed):
    """Single + batched basis construction == the host `_lagrange_basis_modp`."""
    from repro.core.lagrange import _lagrange_basis_modp

    rng = np.random.default_rng(seed)
    ev = rng.choice(4 * (e + j), size=e, replace=False).astype(np.int64)
    # distinct nodes, disjoint from eval points
    pool = np.setdiff1d(np.arange(4 * (e + j), 8 * (e + j)), ev)
    nodes = np.stack([rng.choice(pool, size=j, replace=False) for _ in range(b)])
    got = np.asarray(
        gf.lagrange_basis_gf(ev.astype(np.int32), nodes.astype(np.int32)),
        np.int64,
    )
    assert got.shape == (b, e, j)
    for i in range(b):
        np.testing.assert_array_equal(got[i], _lagrange_basis_modp(ev, nodes[i]))
    # unbatched call gives the same matrix
    got0 = np.asarray(
        gf.lagrange_basis_gf(ev.astype(np.int32), nodes[0].astype(np.int32)),
        np.int64,
    )
    np.testing.assert_array_equal(got0, got[0])


def test_basis_interpolates_polynomials_exactly():
    """The basis actually interpolates: for data = poly(nodes), basis @ data
    == poly(eval) — exactness of the whole encode pipeline in one identity."""
    rng = np.random.default_rng(7)
    nodes = np.arange(20, 29, dtype=np.int64)        # J = 9 -> deg <= 8
    ev = np.arange(0, 11, dtype=np.int64)
    coeffs = rng.integers(0, P, size=9).astype(np.int64)

    def poly(x):
        acc = np.zeros_like(x)
        for c in reversed(coeffs):
            acc = (acc * x + c) % P
        return acc

    basis = gf.lagrange_basis_gf(ev.astype(np.int32), nodes.astype(np.int32))
    got = np.asarray(
        gf.matmul_gf(gf.from_gf(jnp.asarray(basis)),
                     poly(nodes).reshape(-1, 1).astype(np.int32)),
        np.int64,
    )[:, 0]
    np.testing.assert_array_equal(got, poly(ev))


# ---------------------------------------------------------------------------
# bmm_gf: batched exact matmul (the deg-2 gradient's worker-side op)
# ---------------------------------------------------------------------------

@settings(max_examples=12, deadline=None)
@given(b=st.integers(1, 6), m=st.integers(1, 9), c=st.integers(1, 17),
       n=st.integers(1, 7), seed=st.integers(0, 2**31 - 1))
def test_bmm_gf_all_impls_bit_equal_numpy(b, m, c, n, seed):
    rng = np.random.default_rng(seed)
    a = _rand_residues(rng, (b, m, c))
    x = _rand_residues(rng, (b, c, n))
    want = np.stack([
        (a[i].astype(object) @ x[i].astype(object) % P).astype(np.int64)
        for i in range(b)
    ])
    for impl in ("dot", "ref"):
        got = np.asarray(
            gf.bmm_gf(jnp.asarray(a, jnp.int32), jnp.asarray(x, jnp.int32),
                      impl=impl),
            np.int64,
        )
        np.testing.assert_array_equal(got, want, err_msg=impl)


def test_bmm_gf_two_dim_falls_through_and_multi_lead_axes():
    rng = np.random.default_rng(0)
    a = _rand_residues(rng, (4, 5))
    x = _rand_residues(rng, (5, 3))
    np.testing.assert_array_equal(
        np.asarray(gf.bmm_gf(jnp.asarray(a, jnp.int32), jnp.asarray(x, jnp.int32))),
        np.asarray(gf.matmul_gf(jnp.asarray(a, jnp.int32), jnp.asarray(x, jnp.int32))),
    )
    a4 = _rand_residues(rng, (2, 3, 4, 5))
    x4 = _rand_residues(rng, (2, 3, 5, 2))
    got = np.asarray(gf.bmm_gf(jnp.asarray(a4, jnp.int32), jnp.asarray(x4, jnp.int32)), np.int64)
    assert got.shape == (2, 3, 4, 2)
    for i in range(2):
        for j in range(3):
            want = (a4[i, j].astype(object) @ x4[i, j].astype(object) % P).astype(np.int64)
            np.testing.assert_array_equal(got[i, j], want)


def test_bmm_gf_rejects_mismatched_shapes():
    import pytest

    a = jnp.zeros((2, 3, 4), jnp.int32)
    with pytest.raises(ValueError):
        gf.bmm_gf(a, jnp.zeros((3, 4, 2), jnp.int32))     # lead mismatch
    with pytest.raises(ValueError):
        gf.bmm_gf(a, jnp.zeros((2, 5, 2), jnp.int32))     # contraction mismatch
    with pytest.raises(ValueError):
        gf.bmm_gf(a, jnp.zeros((4, 2), jnp.int32))        # rank mismatch


def test_bmm_gf_pallas_interpret_bit_equal_dot():
    """The vmapped-pallas_call branch (TPU default) in interpret mode: same
    residues as the dot/ref paths, including multi-tile shapes."""
    rng = np.random.default_rng(7)
    for b, m, c, n in ((3, 4, 9, 5), (2, 17, 33, 6)):
        a = _rand_residues(rng, (b, m, c))
        x = _rand_residues(rng, (b, c, n))
        pal = np.asarray(gf.bmm_gf(jnp.asarray(a, jnp.int32),
                                   jnp.asarray(x, jnp.int32),
                                   impl="pallas", interpret=True))
        dot = np.asarray(gf.bmm_gf(jnp.asarray(a, jnp.int32),
                                   jnp.asarray(x, jnp.int32), impl="dot"))
        np.testing.assert_array_equal(pal, dot)
