"""Shape/dtype sweep of the Lagrange-encode Pallas kernel vs the jnp oracle."""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core.lagrange import CodeSpec, generator_matrix
from repro.kernels.lagrange_encode.kernel import encode_matrix_pallas
from repro.kernels.lagrange_encode.ref import encode_matrix_ref
from repro.kernels.lagrange_encode import ops


@pytest.mark.parametrize("nr,k", [(6, 4), (15, 10), (150, 50), (33, 7)])
@pytest.mark.parametrize("cols", [64, 500, 1024])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_encode_matrix_matches_ref(nr, k, cols, dtype):
    rng = np.random.default_rng(nr * 1000 + cols)
    g = jnp.asarray(rng.normal(size=(nr, k)), dtype)
    x = jnp.asarray(rng.normal(size=(k, cols)), dtype)
    got = encode_matrix_pallas(g, x, interpret=True)
    want = encode_matrix_ref(g, x)
    assert got.shape == want.shape == (nr, cols)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), rtol=tol, atol=tol
    )


def test_encode_nd_wrapper_matches_core_encode():
    from repro.core.lagrange import encode as core_encode

    spec = CodeSpec(5, 2, 4, 1)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(spec.k, 12, 7)), jnp.float32)
    g = generator_matrix(spec)
    got = ops.encode(g, x, interpret=True)
    want = core_encode(g, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("block_m,block_n", [(8, 128), (128, 256), (64, 512)])
def test_encode_block_shape_sweep(block_m, block_n):
    rng = np.random.default_rng(1)
    g = jnp.asarray(rng.normal(size=(30, 11)), jnp.float32)
    x = jnp.asarray(rng.normal(size=(11, 300)), jnp.float32)
    got = encode_matrix_pallas(g, x, block_m=block_m, block_n=block_n, interpret=True)
    want = encode_matrix_ref(g, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)
