"""Shared test config.  NOTE: XLA_FLAGS/device-count overrides are deliberately
NOT set here — smoke tests and benches must see the single real CPU device.
Multi-device tests spawn subprocesses with their own XLA_FLAGS."""

import os
import sys

# Make `src` importable when pytest is run without PYTHONPATH=src.
_SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)

# Property tests use the REAL `hypothesis` whenever it is installed (genuine
# shrinking in dev environments); only when the package is absent (the pinned
# container) does tests/_hypothesis_stub.py register its deterministic seeded
# fallback so the tests still collect and run.
sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
from _hypothesis_stub import install_if_missing

install_if_missing()
