"""Shared test config.  NOTE: XLA_FLAGS/device-count overrides are deliberately
NOT set here — smoke tests and benches must see the single real CPU device.
Multi-device tests spawn subprocesses with their own XLA_FLAGS."""

import os
import sys

# Make `src` importable when pytest is run without PYTHONPATH=src.
_SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)

# The container has no `hypothesis`; fall back to the deterministic seeded
# stub in tests/_hypothesis_stub.py so property tests still collect and run.
try:
    import hypothesis  # noqa: F401
except ImportError:
    sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
    from _hypothesis_stub import _as_module

    sys.modules["hypothesis"] = _as_module()
    sys.modules["hypothesis.strategies"] = sys.modules["hypothesis"].strategies
