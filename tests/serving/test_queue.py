"""RequestQueue invariants: admit placement, clipping, EDF order, recycling.

All operations are pure jnp updates on a (Q,) pytree; these tests pin the
conventions the serving scan relies on: newcomers fill the lowest-index
free slots, admission clips at capacity (the remainder is the caller's
rejected count), ordering is lexicographic (deadline, arrival, slot index)
with free slots last, and released slots are immediately reusable.
"""

import jax.numpy as jnp
import numpy as np

from repro.serving import queue as rqueue


def _admit(q, t, count, kstar=10, ell_g=2, ell_b=1, deadline_rel=3):
    return rqueue.admit(q, t, count, kstar, ell_g, ell_b, deadline_rel)


def test_admit_fills_lowest_index_free_slots_and_stamps():
    q = rqueue.empty_queue(4)
    q, n = _admit(q, t=5, count=2, kstar=12, ell_g=3, deadline_rel=2)
    assert int(n) == 2
    np.testing.assert_array_equal(
        np.asarray(q.occupied), [True, True, False, False]
    )
    np.testing.assert_array_equal(np.asarray(q.kstar)[:2], [12, 12])
    np.testing.assert_array_equal(np.asarray(q.deadline_abs)[:2], [7, 7])
    np.testing.assert_array_equal(np.asarray(q.arrival)[:2], [5, 5])
    # a newcomer lands in the hole, not after the tail
    q = rqueue.release(q, jnp.asarray([True, False, False, False]))
    q, n = _admit(q, t=6, count=1)
    assert int(n) == 1
    np.testing.assert_array_equal(
        np.asarray(q.occupied), [True, True, False, False]
    )
    assert int(q.arrival[0]) == 6 and int(q.arrival[1]) == 5


def test_admit_clips_at_free_capacity():
    q = rqueue.empty_queue(3)
    q, n = _admit(q, t=0, count=5)
    assert int(n) == 3                      # 2 are the caller's rejects
    assert bool(q.occupied.all())
    q, n = _admit(q, t=1, count=4)
    assert int(n) == 0


def test_edf_order_deadline_then_fifo_then_slot_index():
    q = rqueue.empty_queue(5)
    # slot 0: dl 9 arr 2 | slot 1: dl 4 arr 3 | slot 2: dl 4 arr 1
    # slot 3: free       | slot 4: dl 4 arr 1 (slot-index tie with 2)
    q = rqueue.RequestQueue(
        occupied=jnp.asarray([True, True, True, False, True]),
        kstar=q.kstar, ell_g=q.ell_g, ell_b=q.ell_b,
        deadline_abs=jnp.asarray([9, 4, 4, 0, 4], jnp.int32),
        arrival=jnp.asarray([2, 3, 1, 0, 1], jnp.int32),
    )
    order = np.asarray(rqueue.edf_order(q))
    # dl 4 before dl 9; among dl 4: arrival 1 (slots 2, 4 in index order)
    # before arrival 3 (slot 1); free slot last
    np.testing.assert_array_equal(order, [2, 4, 1, 0, 3])


def test_release_recycles_and_is_a_pure_mask_update():
    q = rqueue.empty_queue(2)
    q, _ = _admit(q, t=0, count=2)
    q2 = rqueue.release(q, jnp.asarray([False, True]))
    np.testing.assert_array_equal(np.asarray(q2.occupied), [True, False])
    # parameters are left stale on purpose: free slots are padding
    np.testing.assert_array_equal(np.asarray(q2.kstar), np.asarray(q.kstar))
    q3, n = _admit(q2, t=4, count=2)
    assert int(n) == 1 and bool(q3.occupied.all())
    assert int(q3.arrival[1]) == 4
