"""allocate_queue: the multi-job EDF water-filling extension of
allocate_masked.

Pinned properties:

  * ONE active slot == ``allocate_masked`` on the full pool, bit for bit
    (the degenerate case that reduces serving to the single-job engine);
  * segments are disjoint, confined to the valid pool, zero for inactive
    slots, and ordered by priority over descending-p_good ranks;
  * the most urgent slot absorbs all surplus (later slots keep exactly
    their minimal reserves);
  * oversubscription is EXPLICIT: slots whose segment cannot reach kstar
    read ``feasible == False`` (never a silent short allocation).
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import lea


def _rand_pgood(seed, n):
    return jax.random.uniform(jax.random.PRNGKey(seed), (n,), minval=0.05,
                              maxval=0.95)


def test_single_active_slot_is_allocate_masked_bitwise():
    n, q = 12, 3
    for seed in range(5):
        p = _rand_pgood(seed, n)
        mask = jnp.arange(n) < 9                      # 3 padding workers
        active = jnp.asarray([False, True, False])
        ks = jnp.asarray([7, 20, 3], jnp.int32)
        eg = jnp.asarray([2, 4, 1], jnp.int32)
        eb = jnp.asarray([1, 2, 1], jnp.int32)
        order = jnp.asarray([1, 0, 2], jnp.int32)
        loads, i_star, feas = lea.allocate_queue(
            p, mask, active, ks, eg, eb, order
        )
        ref_loads, ref_i, ref_feas = lea.allocate_masked(
            p, lea.PoolLoad(kstar=ks[1], ell_g=eg[1], ell_b=eb[1], mask=mask)
        )
        np.testing.assert_array_equal(np.asarray(loads[1]),
                                      np.asarray(ref_loads))
        assert int(i_star[1]) == int(ref_i) and bool(feas[1]) == bool(ref_feas)
        # inactive slots: nothing assigned, explicitly infeasible
        assert int(jnp.sum(loads[0]) + jnp.sum(loads[2])) == 0
        assert not bool(feas[0]) and not bool(feas[2])


def test_segments_are_disjoint_and_inside_the_valid_pool():
    n, q = 16, 4
    p = _rand_pgood(42, n)
    mask = jnp.arange(n) < 14
    active = jnp.asarray([True, True, False, True])
    ks = jnp.full((q,), 6, jnp.int32)
    eg = jnp.full((q,), 2, jnp.int32)
    eb = jnp.full((q,), 1, jnp.int32)
    order = jnp.asarray([3, 0, 1, 2], jnp.int32)
    loads, _, feas = lea.allocate_queue(p, mask, active, ks, eg, eb, order)
    assigned = np.asarray(loads) > 0                   # (Q, n)
    assert (assigned.sum(axis=0) <= 1).all()           # disjoint
    assert not assigned[:, 14:].any()                  # padding untouched
    assert not assigned[2].any()                       # inactive slot
    assert bool(feas[0]) and bool(feas[1]) and bool(feas[3])


def test_most_urgent_slot_absorbs_all_surplus():
    n = 10
    p = _rand_pgood(7, n)
    mask = jnp.ones((n,), bool)
    active = jnp.asarray([True, True])
    # minimal demands: ceil(8/4)=2 each; surplus = 10 - 4 = 6
    ks = jnp.asarray([8, 8], jnp.int32)
    eg = jnp.asarray([4, 4], jnp.int32)
    eb = jnp.asarray([1, 1], jnp.int32)
    # slot 1 is most urgent
    loads, _, feas = lea.allocate_queue(
        p, mask, active, ks, eg, eb, jnp.asarray([1, 0], jnp.int32)
    )
    seg_sizes = (np.asarray(loads) > 0).sum(axis=1)
    # urgent slot's segment may leave trailing zero-load workers (the DP can
    # stop short of its segment), so count via the reserve arithmetic
    assert bool(feas[0]) and bool(feas[1])
    assert seg_sizes[0] <= 2                           # back slot: minimal
    # urgent slot got the 8 best-ranked workers (6 surplus + its minimal 2):
    # the back slot's workers are exactly the 2 worst-ranked assigned ones
    ranks = np.asarray(jnp.argsort(jnp.argsort(-p)))
    urgent_ranks = ranks[np.asarray(loads[1]) > 0]
    back_ranks = ranks[np.asarray(loads[0]) > 0]
    if back_ranks.size:
        assert urgent_ranks.max() < back_ranks.min()


def test_oversubscription_is_explicitly_infeasible():
    n = 6
    p = _rand_pgood(3, n)
    mask = jnp.ones((n,), bool)
    active = jnp.ones((3,), bool)
    # each slot needs ceil(8/2) = 4 workers; 3 slots need 12 > 6
    ks = jnp.full((3,), 8, jnp.int32)
    eg = jnp.full((3,), 2, jnp.int32)
    eb = jnp.full((3,), 1, jnp.int32)
    order = jnp.asarray([0, 1, 2], jnp.int32)
    loads, _, feas = lea.allocate_queue(p, mask, active, ks, eg, eb, order)
    feas = np.asarray(feas)
    assert feas[0]                       # highest priority fits (4 <= 6)
    assert not feas[1] and not feas[2]   # the rest are explicit shortfalls
    # and the infeasible slots were not silently over-allocated
    assert (np.asarray(loads).sum(axis=1) <= n * 2).all()


def test_priority_permutation_only_reorders_slot_results():
    """Same slots, same priority CONTENT, different slot labelling: the
    returned rows follow the original slot ids (order is unpermuted)."""
    n = 8
    p = _rand_pgood(11, n)
    mask = jnp.ones((n,), bool)
    ks = jnp.asarray([4, 9], jnp.int32)
    eg = jnp.asarray([2, 3], jnp.int32)
    eb = jnp.asarray([1, 1], jnp.int32)
    la, ia, fa = lea.allocate_queue(
        p, mask, jnp.ones((2,), bool), ks, eg, eb,
        jnp.asarray([0, 1], jnp.int32),
    )
    lb, ib, fb = lea.allocate_queue(
        p, mask, jnp.ones((2,), bool), jnp.flip(ks), jnp.flip(eg),
        jnp.flip(eb), jnp.asarray([1, 0], jnp.int32),
    )
    np.testing.assert_array_equal(np.asarray(la), np.asarray(jnp.flip(lb, 0)))
    np.testing.assert_array_equal(np.asarray(fa), np.asarray(jnp.flip(fb)))
