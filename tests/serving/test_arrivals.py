"""Arrival processes: registry contract, exactness, determinism, stream keys.

The arrival stream is engine input, so the properties that matter are the
engine's: registered-by-name construction, bit-determinism per key, int32
counts, and a DEDICATED key tag (arrival randomness must never perturb the
trajectory / policy / fault streams — the idle-stream bit-identity test in
test_engine.py is the end-to-end check of that).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import serving
from repro.serving import arrivals


def test_registry_names_and_unknown_process():
    names = serving.process_names()
    for name in ("constant", "mmpp", "poisson", "shift_exp"):
        assert name in names
    with pytest.raises(KeyError, match="shift_exp"):
        serving.make_process("no_such_process")


def test_constant_is_exact_and_consumes_no_randomness():
    p = serving.make_process("constant", per_round=3)
    a = serving.sample_arrivals(jax.random.PRNGKey(0), p, 7)
    b = serving.sample_arrivals(jax.random.PRNGKey(99), p, 7)
    assert a.dtype == jnp.int32
    np.testing.assert_array_equal(np.asarray(a), np.full(7, 3))
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_poisson_deterministic_per_key_and_mean():
    p = serving.make_process("poisson", rate=1.5)
    a = serving.sample_arrivals(jax.random.PRNGKey(1), p, 4000)
    b = serving.sample_arrivals(jax.random.PRNGKey(1), p, 4000)
    c = serving.sample_arrivals(jax.random.PRNGKey(2), p, 4000)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert not np.array_equal(np.asarray(a), np.asarray(c))
    assert a.dtype == jnp.int32 and (np.asarray(a) >= 0).all()
    assert abs(float(jnp.mean(a.astype(jnp.float32))) - 1.5) < 0.1


def test_shift_exp_binning_and_rate():
    # mean gap t_c + mean = 0.5 rounds -> ~2 arrivals per round
    p = serving.make_process("shift_exp", t_const=0.1, mean=0.4)
    a = serving.sample_arrivals(jax.random.PRNGKey(3), p, 2000)
    b = serving.sample_arrivals(jax.random.PRNGKey(3), p, 2000)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert (np.asarray(a) >= 0).all()
    rate = float(jnp.mean(a.astype(jnp.float32)))
    assert abs(rate - 2.0) < 0.25
    # a pure-constant gap of exactly 1 round: one arrival per round from
    # round 1 on (the first event fires at t = 1.0)
    p1 = serving.make_process("shift_exp", t_const=1.0, mean=0.0)
    a1 = np.asarray(serving.sample_arrivals(jax.random.PRNGKey(4), p1, 50))
    assert a1[0] == 0 and (a1[1:] == 1).all()


def test_mmpp_modulates_between_the_two_rates():
    p = serving.make_process("mmpp", rate_lo=0.2, rate_hi=4.0,
                             p_stay_lo=0.9, p_stay_hi=0.7)
    a = np.asarray(serving.sample_arrivals(jax.random.PRNGKey(5), p, 4000))
    assert (a >= 0).all()
    assert 0.2 < a.mean() < 4.0


def test_arrival_key_is_a_dedicated_stream():
    key = jax.random.PRNGKey(0)
    ak = serving.arrival_key(key)
    assert not np.array_equal(np.asarray(ak), np.asarray(key))
    from repro.faults.channels import fault_key

    assert not np.array_equal(np.asarray(ak), np.asarray(fault_key(key)))
    # deterministic: same key, same derived stream
    np.testing.assert_array_equal(
        np.asarray(ak), np.asarray(serving.arrival_key(jax.random.PRNGKey(0)))
    )


def test_sample_arrivals_derives_the_tag_itself():
    """sample_arrivals consumes arrival_key(key), not key — two processes on
    the same base key see independent tagged streams, and feeding the raw
    key elsewhere cannot collide with arrivals."""
    p = serving.make_process("poisson", rate=1.0)
    key = jax.random.PRNGKey(7)
    via_api = serving.sample_arrivals(key, p, 100)
    direct = p.sample(arrivals.arrival_key(key), 100)
    np.testing.assert_array_equal(np.asarray(via_api), np.asarray(direct))
