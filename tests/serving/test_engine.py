"""Serving engine acceptance: bit-identity, idle identity, conservation,
compile-once, fault composition.

The contract that makes repro.serving an EXTENSION of the offline engine
rather than a second engine:

  * a single-slot queue fed exactly one always-admitted request per round
    with ``deadline_rel = 0`` reproduces the single-job engine
    (``simulate_strategies_pool``) BIT-IDENTICALLY on the same key;
  * a zero-arrival run is the idle engine: every counter and event is
    zero, and the engine streams are untouched (``serve_rollout`` states
    == ``rollout_pool`` states, bit for bit);
  * every request ends in exactly one disposition (conservation), under
    load and under admission control;
  * a whole arrival-rate x deadline x admission grid is ONE compile
    (``serving_compile_cache_size``), and each sweep row equals the
    unbatched ``simulate_serving`` on its own key;
  * fault channels compose on the time axis only — packet-axis injectors
    are rejected loudly.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import faults, serving
from repro.core import lea, throughput

N = 15
MU_G, MU_B, D = 10.0, 3.0, 1.0
P_GG, P_BB = 0.8, 0.7
KS, EG, EB = 50, 10, 3
ROUNDS = 120

_MASK = jnp.ones((N,), bool)
_PGG = jnp.full((N,), P_GG)
_PBB = jnp.full((N,), P_BB)


def _admit_all_spec(deadline_rel=0):
    return serving.RequestSpec(
        kstar=KS, ell_g=EG, ell_b=EB, deadline_rel=deadline_rel,
        admit_threshold=0.0, reserve_cap=serving.ADMIT_ALL_CAP,
    )


def test_degenerate_single_slot_is_the_offline_engine_bitwise():
    key = jax.random.PRNGKey(7)
    out = serving.simulate_serving(
        key, _MASK, _PGG, _PBB, MU_G, MU_B, D, _admit_all_spec(),
        serving.make_process("constant", per_round=1),
        rounds=ROUNDS, strategies=("lea",), capacity=1,
    )
    pool = lea.PoolLoad(kstar=jnp.int32(KS), ell_g=jnp.int32(EG),
                        ell_b=jnp.int32(EB), mask=_MASK)
    succ = throughput.simulate_strategies_pool(
        key, pool, _PGG, _PBB, MU_G, MU_B, D, ROUNDS, strategies=("lea",)
    )
    succ_col = np.asarray(succ)[:, 0].astype(bool)
    served = np.asarray(out.events)[0, :, 0] == serving.EVENT_ON_TIME
    np.testing.assert_array_equal(served, succ_col)
    assert int(out.served_on_time[0]) == int(succ_col.sum())
    # deadline_rel=0 + grace=0: the round's miss expires the same round
    expired = np.asarray(out.events)[0, :, 0] == serving.EVENT_EXPIRED
    np.testing.assert_array_equal(expired, ~succ_col)
    assert int(out.arrivals[0]) == int(out.admitted[0]) == ROUNDS
    assert int(out.rejected[0]) == int(out.in_flight[0]) == 0
    # every served request took exactly one round
    sojourn = np.asarray(out.sojourn)[0, :, 0]
    np.testing.assert_array_equal(sojourn[served], 1)


def test_zero_arrivals_is_the_idle_engine():
    key = jax.random.PRNGKey(3)
    out = serving.simulate_serving(
        key, _MASK, _PGG, _PBB, MU_G, MU_B, D, _admit_all_spec(),
        serving.make_process("constant", per_round=0),
        rounds=ROUNDS, strategies=("lea",), capacity=4,
    )
    for field in ("arrivals", "admitted", "served_on_time", "served_late",
                  "rejected", "expired", "in_flight"):
        assert int(getattr(out, field)[0]) == 0, field
    assert not np.asarray(out.events).any()
    assert not np.asarray(out.sojourn).any()
    # and the engine streams were untouched by the serving machinery
    states_s, _ = throughput.serve_rollout(
        key, _MASK, _PGG, _PBB, ROUNDS, ("lea",)
    )
    pool = lea.PoolLoad(kstar=jnp.int32(KS), ell_g=jnp.int32(EG),
                        ell_b=jnp.int32(EB), mask=_MASK)
    states_r, _, _ = throughput.rollout_pool(
        key, pool, _PGG, _PBB, ROUNDS, strategies=("lea",)
    )
    np.testing.assert_array_equal(np.asarray(states_s), np.asarray(states_r))


def test_conservation_under_overload_and_admission_control():
    key = jax.random.PRNGKey(11)
    for thr, cap in ((0.0, serving.ADMIT_ALL_CAP), (0.5, 0.7)):
        out = serving.simulate_serving(
            key, _MASK, _PGG, _PBB, MU_G, MU_B, D,
            serving.RequestSpec(kstar=KS, ell_g=EG, ell_b=EB,
                                deadline_rel=2, admit_threshold=thr,
                                reserve_cap=cap),
            serving.make_process("poisson", rate=3.0),
            rounds=ROUNDS, strategies=("lea",), capacity=5,
        )
        arr = int(out.arrivals[0])
        assert arr == int(out.admitted[0]) + int(out.rejected[0])
        assert int(out.admitted[0]) == (
            int(out.served_on_time[0]) + int(out.served_late[0])
            + int(out.expired[0]) + int(out.in_flight[0])
        )
        # per-slot events reconcile with the counters
        ev = np.asarray(out.events)[0]
        assert (ev == serving.EVENT_ON_TIME).sum() == int(out.served_on_time[0])
        assert (ev == serving.EVENT_EXPIRED).sum() == int(out.expired[0])


def test_sweep_serving_compiles_once_and_matches_unbatched_rows():
    b = 3
    keys = jax.vmap(lambda i: jax.random.PRNGKey(100 + i))(jnp.arange(b))
    pool_mask = jnp.ones((b, N), bool)
    p_gg = jnp.broadcast_to(_PGG, (b, N))
    p_bb = jnp.broadcast_to(_PBB, (b, N))
    rates = jnp.asarray([0.5, 1.5, 3.0], jnp.float32)
    spec = serving.RequestSpec(
        kstar=KS, ell_g=EG, ell_b=EB,
        deadline_rel=jnp.asarray([1, 2, 3], jnp.int32),
        admit_threshold=0.4, reserve_cap=0.8,
    )
    kwargs = dict(rounds=64, strategies=("lea",), capacity=3)
    c0 = serving.serving_compile_cache_size()
    out = serving.sweep_serving(
        keys, pool_mask, p_gg, p_bb, MU_G, MU_B, D, spec,
        serving.make_process("poisson", rate=rates), **kwargs,
    )
    # a second grid with DIFFERENT traced parameters: same compile
    serving.sweep_serving(
        keys, pool_mask, p_gg, p_bb, MU_G, MU_B, D,
        spec._replace(admit_threshold=0.0,
                      reserve_cap=serving.ADMIT_ALL_CAP),
        serving.make_process("poisson", rate=rates * 0.5), **kwargs,
    )
    assert serving.serving_compile_cache_size() - c0 == 1
    # row i == the unbatched engine on row i's key and parameters
    for i in range(b):
        single = serving.simulate_serving(
            keys[i], pool_mask[i], p_gg[i], p_bb[i], MU_G, MU_B, D,
            serving.RequestSpec(
                kstar=KS, ell_g=EG, ell_b=EB,
                deadline_rel=spec.deadline_rel[i],
                admit_threshold=0.4, reserve_cap=0.8,
            ),
            serving.make_process("poisson", rate=rates[i]), **kwargs,
        )
        for field in ("arrivals", "admitted", "served_on_time",
                      "rejected", "expired", "in_flight"):
            assert int(getattr(out, field)[i, 0]) == int(
                getattr(single, field)[0]
            ), (field, i)


def test_time_axis_channel_composes_and_packet_axis_is_rejected():
    key = jax.random.PRNGKey(5)
    base = serving.simulate_serving(
        key, _MASK, _PGG, _PBB, MU_G, MU_B, D, _admit_all_spec(2),
        serving.make_process("constant", per_round=1),
        rounds=ROUNDS, strategies=("lea",), capacity=2,
    )
    faulted = serving.simulate_serving(
        key, _MASK, _PGG, _PBB, MU_G, MU_B, D, _admit_all_spec(2),
        serving.make_process("constant", per_round=1),
        rounds=ROUNDS, strategies=("lea",), capacity=2,
        channel=faults.make_channel([("preempt", {"p_preempt": 0.4})]),
    )
    # preemption only shrinks the compute window: never more served
    assert int(faulted.served_on_time[0]) <= int(base.served_on_time[0])
    with pytest.raises(ValueError, match="packet"):
        serving.simulate_serving(
            key, _MASK, _PGG, _PBB, MU_G, MU_B, D, _admit_all_spec(),
            serving.make_process("constant", per_round=1),
            rounds=8, strategies=("lea",), capacity=1,
            channel=faults.make_channel(
                [("packet_bernoulli", {"p_drop": 0.1})]
            ),
        )


def test_static_strategies_are_rejected_by_serve_rollout():
    with pytest.raises(ValueError):
        throughput.serve_rollout(
            jax.random.PRNGKey(0), _MASK, _PGG, _PBB, 8, ("static",)
        )
