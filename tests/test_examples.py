"""Smoke gate for the examples/ drivers: each runs end-to-end (subprocess,
reduced round counts via the REPRO_*_ROUNDS knobs) and prints its final OK."""

import os
import subprocess
import sys

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_example(script: str, env_extra: dict[str, str], timeout: int = 600):
    env = dict(os.environ)
    env["PYTHONPATH"] = (
        os.path.join(_ROOT, "src") + os.pathsep + env.get("PYTHONPATH", "")
    ).rstrip(os.pathsep)
    env.update(env_extra)
    proc = subprocess.run(
        [sys.executable, os.path.join(_ROOT, "examples", script)],
        capture_output=True, text=True, timeout=timeout, env=env, cwd=_ROOT,
    )
    assert proc.returncode == 0, f"{script} failed:\n{proc.stdout}\n{proc.stderr}"
    assert proc.stdout.rstrip().endswith("OK"), proc.stdout
    return proc.stdout


def test_quickstart_example():
    out = _run_example("quickstart.py", {"REPRO_QUICKSTART_ROUNDS": "150"})
    assert "recovery threshold" in out and "fig3_scenario4" in out


def test_coded_regression_example():
    out = _run_example("coded_regression.py", {"REPRO_EXAMPLE_ROUNDS": "80"})
    assert "timely throughput" in out


def test_serve_coded_example():
    out = _run_example("serve_coded.py", {"REPRO_EXAMPLE_ROUNDS": "60"})
    assert "timely computation throughput" in out
    assert "served on time" in out
